"""AccuGraph [Ya18] — vertex-centric pull accelerator model.

Faithful to paper Sect. 3.3 / Fig. 8:

* inverse-CSR blocks per source interval (values of the interval resident
  in BRAM while the block is processed); single DDR4-2400R channel.
* Per block: sequential *prefetch* of the interval's values; *destination
  value + pointer* streams (values filtered by BRAM residency, merged
  round-robin with pointers, paced by 8 vertex pipelines); *neighbor*
  stream (sequential CSR, paced by 16 edge pipelines **and stalled by
  vertex-cache bank conflicts** — 16 BRAM banks, one value per cycle
  each); changed-only value *writes* (highest priority).
* Asynchronous accumulation: value changes apply directly to BRAM, which
  is why AccuGraph needs fewer iterations than HitGraph (Fig. 12b) — the
  iteration structure comes from the asynchronous JAX sweep engine.

Sect. 5 enhancements (both modelled, default off to match the baseline):
*prefetch skipping* (skip re-prefetch when the previous processed block is
the same) and *partition skipping* (dirty-bit per interval).

Vectorized realization: a block's destination-value / pointer / neighbor
streams are *static* across iterations, so they are built (and
priority-sorted) once at model construction; each iteration only computes
the changed-value write lines and splices them into the pre-sorted static
stream with a stable two-pointer merge (``searchsorted``), emitting the
whole run as one :class:`~repro.core.trace.SegmentedTrace` that is packed
on device and served by the fused DRAM scan.  Like HitGraph, the emitted
program is a function of the DRAM geometry and clock only (timing is a
traced scan input), which is what the sweep engine's geometry-keyed pack
cache exploits.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.algorithms import vertex_centric
from repro.algorithms.common import Problem, RunResult
from repro.core.accel import SimReport, VectorizedDRAM
from repro.core.dram import (CACHE_LINE_BYTES, DRAMConfig, MemoryLayout,
                             ddr4_2400r)
from repro.core.hitgraph import CONTIGUOUS_ORDER, _line_span, _spread
from repro.core.trace import SegmentedTrace, bulk_issue
from repro.graphs.formats import CSRPartitions, Graph


@dataclasses.dataclass(frozen=True)
class AccuGraphConfig:
    """Tab. 4 'AccuGraph' row (reproducibility defaults)."""

    vertex_pipelines: int = 8
    edge_pipelines: int = 16
    partition_elements: Optional[int] = None    # None -> all in BRAM
    acc_ghz: float = 0.2
    value_bytes: int = 4          # 1 for BFS (Tab. 3: 8-bit values)
    pointer_bytes: int = 4
    neighbor_bytes: int = 4
    vertex_cache_banks: int = 16
    vertex_cache_ports: int = 2       # BRAM is dual-ported
    model_stalls: bool = True
    prefetch_skipping: bool = False             # paper Sect. 5 (ours)
    partition_skipping: bool = False            # paper Sect. 5 (ours)
    dram: Optional[DRAMConfig] = None
    dram_density: str = "4Gb"

    def dram_config(self) -> DRAMConfig:
        if self.dram is not None:
            return self.dram
        base = ddr4_2400r(channels=1, ranks=1, density=self.dram_density)
        return dataclasses.replace(base, order=CONTIGUOUS_ORDER)


class AccuGraphModel:
    def __init__(self, g: Graph, cfg: AccuGraphConfig = AccuGraphConfig()):
        self.cfg = cfg
        self.g = g
        self.dram = cfg.dram_config()
        self.q = (cfg.partition_elements if cfg.partition_elements
                  else g.n)
        self.parts = CSRPartitions.build(g, self.q)
        self.p = self.parts.p
        self._layout()
        self._stall_cycles = [self._block_stalls(k) for k in range(self.p)]
        self._precompute_streams()

    def _layout(self) -> None:
        cfg = self.cfg
        lay = MemoryLayout()
        self.values_base = lay.allocate(
            "values", self.g.n * cfg.value_bytes)
        self.ptr_base: List[int] = []
        self.nbr_base: List[int] = []
        for k in range(self.p):
            blk = self.parts.blocks[k]
            self.ptr_base.append(lay.allocate(
                f"pointers_{k}", (self.g.n + 1) * cfg.pointer_bytes))
            self.nbr_base.append(lay.allocate(
                f"neighbors_{k}", blk.m * cfg.neighbor_bytes))
        if lay.total_bytes > self.dram.capacity_bytes:
            raise ValueError("graph does not fit DRAM capacity; scale down")
        self.layout = lay

    def _block_stalls(self, k: int) -> int:
        """Vertex-cache bank-conflict-adjusted cycles to stream block k's
        neighbors (paper Sect. 3.3: 16 BRAM banks; a neighbor's value
        request stalls until its bank can serve it).

        Hardware detail (AccuGraph's data-conflict management): identical
        ids within a group are served by a single broadcast read, banks
        are dual-ported, and requests queue per bank rather than stalling
        the whole front per cycle — so the block's neighbor stream takes
        ``max(ideal, max_b ceil(total_distinct_requests_b / ports))``
        cycles.  Stalls therefore only bite when bank *totals* are skewed
        (hot id residues), matching the original article's observation
        that stalls matter yet throughput stays near 16 edges/cycle on
        well-behaved graphs."""
        cfg = self.cfg
        nbrs = self.parts.blocks[k].neighbors
        m_k = len(nbrs)
        ep = cfg.edge_pipelines
        ideal = int(np.ceil(m_k / ep))
        if not cfg.model_stalls or m_k == 0:
            return ideal
        banks = cfg.vertex_cache_banks
        pad = (-m_k) % ep
        ids = np.concatenate(
            [nbrs, np.full(pad, -1, dtype=np.int64)])
        groups = ids.reshape(-1, ep)
        rows = np.repeat(np.arange(len(groups), dtype=np.int64), ep)
        flat = groups.ravel()
        valid = flat >= 0
        # broadcast: only *distinct* ids per (group, bank) occupy a port
        keys = (rows[valid] << 32) + flat[valid]
        uniq = np.unique(keys)
        u_banks = (uniq & 0xFFFFFFFF) % banks
        per_bank = np.bincount(u_banks, minlength=banks)
        queued = int(np.ceil(per_bank.max() / cfg.vertex_cache_ports))
        return max(ideal, queued)

    def _precompute_streams(self) -> None:
        """Per-block streams that do not change across iterations: the
        prefetch trace and the priority-sorted (dv + pointer + neighbor)
        read stream.  Built once; iterations only merge in the
        changed-value writes."""
        cfg, n = self.cfg, self.g.n
        vb, pb, nb = cfg.value_bytes, cfg.pointer_bytes, cfg.neighbor_bytes
        ratio = self.dram.clock_ghz / cfg.acc_ghz
        self._ratio = ratio
        v_window = int(np.ceil(n / cfg.vertex_pipelines) * ratio)
        self._prefetch: List[np.ndarray] = []
        self._static_line: List[np.ndarray] = []
        self._static_issue: List[np.ndarray] = []
        self._e_window: List[int] = []
        for k in range(self.p):
            s, e = self.parts.intervals[k]
            self._prefetch.append(
                _line_span(self.values_base + s * vb, (e - s) * vb))
            # destination value stream (filtered by BRAM residency)
            # + pointer stream, vertex-pipeline paced
            dv_lines = np.concatenate([
                _line_span(self.values_base, s * vb),
                _line_span(self.values_base + e * vb, (n - e) * vb),
            ])
            dv_issue = _spread(len(dv_lines), 0, v_window)
            ptr_lines = _line_span(self.ptr_base[k], (n + 1) * pb)
            ptr_issue = _spread(len(ptr_lines), 0, v_window)
            # neighbor stream, edge-pipeline paced + cache stalls
            m_k = self.parts.blocks[k].m
            nl = _line_span(self.nbr_base[k], m_k * nb)
            e_window = int(self._stall_cycles[k] * ratio)
            nl_issue = _spread(len(nl), 0, max(e_window, 1))
            line = np.concatenate([dv_lines, ptr_lines, nl])
            issue = np.concatenate([dv_issue, ptr_issue, nl_issue])
            order = np.argsort(issue, kind="stable")  # priority merge
            self._static_line.append(line[order])
            self._static_issue.append(issue[order])
            self._e_window.append(e_window)

    def _block_phase(self, k: int, changed_k: np.ndarray):
        """One block's phase trace: splice this iteration's changed-value
        writes (highest priority on ties is *not* reordered — the static
        streams registered first win equal issue cycles, exactly like the
        legacy concat + stable sort) into the pre-sorted static stream."""
        cfg = self.cfg
        wdst = np.nonzero(changed_k)[0]
        w_line = (self.values_base
                  + wdst * cfg.value_bytes) // CACHE_LINE_BYTES
        if len(w_line):                       # ascending -> adjacent dedup
            keep = np.empty(len(w_line), dtype=bool)
            keep[0] = True
            np.not_equal(w_line[1:], w_line[:-1], out=keep[1:])
            w_line = w_line[keep]
        w_issue = _spread(len(w_line), 0, max(self._e_window[k], 1))
        s_line, s_issue = self._static_line[k], self._static_issue[k]
        n_s, n_w = len(s_line), len(w_line)
        # stable merge (static side wins ties, matching concat order)
        pos_w = np.searchsorted(s_issue, w_issue, side="right") \
            + np.arange(n_w, dtype=np.int64)
        pos_s = np.searchsorted(w_issue, s_issue, side="left") \
            + np.arange(n_s, dtype=np.int64)
        line = np.empty(n_s + n_w, dtype=np.int64)
        issue = np.empty(n_s + n_w, dtype=np.int64)
        wr = np.zeros(n_s + n_w, dtype=bool)
        line[pos_s] = s_line
        line[pos_w] = w_line
        issue[pos_s] = s_issue
        issue[pos_w] = w_issue
        wr[pos_w] = True
        return line, wr, issue

    # ------------------------------------------------------------------
    def build_program(self, problem: Problem,
                      run: RunResult) -> SegmentedTrace:
        """Emit every phase of the whole run up front (prefetch + block
        phases per iteration, phase-relative issues)."""
        cfg = self.cfg
        phases = []
        last_prefetched = -1
        for it, st in enumerate(run.per_iter):
            for k in range(self.p):
                changed_k = (st.changed_per_block[k]
                             if st.changed_per_block is not None else None)
                if changed_k is None:
                    continue        # block skipped (partition skipping)
                # 1. prefetch interval values into BRAM.  The block body
                #    *pulls from BRAM*, so it waits for the prefetch to
                #    complete — this serial latency is exactly what the
                #    paper's prefetch-skipping enhancement removes.
                if not (cfg.prefetch_skipping and last_prefetched == k):
                    pre = self._prefetch[k]
                    phases.append((f"it{it}_b{k}_prefetch", pre,
                                   np.zeros(len(pre), dtype=bool),
                                   bulk_issue(len(pre), 0)))
                last_prefetched = k
                phases.append((f"it{it}_b{k}",
                               *self._block_phase(k, changed_k)))
        return SegmentedTrace.from_phases(phases)

    def make_report(self, problem: Problem, run: RunResult,
                    stats) -> SimReport:
        """Assemble the report from any executed DRAM-stats surface."""
        total_bytes = sum(ph.bytes for ph in stats.phases)
        return SimReport(
            system="accugraph", problem=problem.value, graph=self.g.name,
            runtime_ns=stats.now / self.dram.clock_ghz,
            iterations=run.iterations, edges=self.g.m, vertices=self.g.n,
            total_requests=stats.total_requests, total_bytes=total_bytes,
            row_hit_rate=(stats.total_row_hits
                          / max(stats.total_requests, 1)),
            phases=stats.phases,
            cache_lookups=getattr(stats, "cache_lookups", 0),
            cache_hits=getattr(stats, "cache_hits", 0),
            prefetch_hits=getattr(stats, "prefetch_hits", 0),
        )

    def simulate(self, problem: Problem, root: int = 0,
                 fixed_iters: Optional[int] = None,
                 run: Optional[RunResult] = None,
                 memory_system=None) -> SimReport:
        """Simulate; ``memory_system`` injects a DRAM backend (any object
        with the :class:`VectorizedDRAM` program/phase interface, e.g.
        the event-driven ``repro.sim.backends.EventDRAM``)."""
        cfg = self.cfg
        if run is None:
            run = vertex_centric.run(
                self.g, problem, q=self.q, root=root,
                fixed_iters=fixed_iters,
                block_skipping=cfg.partition_skipping,
            )
        dram = (memory_system if memory_system is not None
                else VectorizedDRAM(self.dram))
        dram.run_program(self.build_program(problem, run))
        return self.make_report(problem, run, dram)


def simulate(g: Graph, problem: Problem,
             cfg: AccuGraphConfig = AccuGraphConfig(), root: int = 0,
             fixed_iters: Optional[int] = None) -> SimReport:
    """Deprecated shim — use :func:`repro.sim.simulate` with
    ``accelerator="accugraph"`` (single entry point for all accelerators,
    memory types, and backends)."""
    from repro import sim
    return sim.simulate(sim.ScenarioSpec(
        g, problem, accelerator="accugraph", config=cfg, root=root,
        fixed_iters=fixed_iters))
