"""Analytical performance model (the paper's §7 future work, built).

Closed-form runtime estimate per (accelerator, problem, graph) without
trace simulation: each phase's duration is the max of

* the producer window (pipeline rate limits),
* the DRAM service bound: ``bytes / achievable_bandwidth``, where the
  achievable bandwidth is derived from the *stream mix* — sequential
  streams approach the bus peak, interleaved k-way stream mixes and
  random writes degrade by a row-conflict model calibrated against the
  trace simulator (``tests/test_analytical.py`` asserts agreement).

Use cases: O(1) design-space sweeps (partition size, pipeline counts,
DRAM type) before running the trace simulator on the shortlist.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.algorithms import edge_centric, vertex_centric
from repro.algorithms.common import Problem
from repro.core.accugraph import AccuGraphConfig
from repro.core.dram import CACHE_LINE_BYTES, DRAMConfig
from repro.core.hitgraph import HitGraphConfig
from repro.graphs.formats import Graph, partition_intervals


def _achievable_fraction(cfg: DRAMConfig, n_streams: int,
                         random_frac: float) -> float:
    """Calibrated achievable-bandwidth fraction for a stream mix.

    ``n_streams`` concurrently interleaved sequential streams cause a row
    switch roughly every ``lines_per_row / n_streams`` lines when streams
    collide in a bank; fully random traffic pays the ACT-rate limits
    (tRRD/tFAW) — the same effects the trace simulator resolves exactly.
    """
    t = cfg.timing
    lines_per_row = cfg.org.lines_per_row
    # sequential component: amortized row-switch overhead
    switch_every = max(lines_per_row / max(n_streams, 1), 1.0)
    seq_cost = t.tBL + (t.tRP + t.tRCD) / switch_every
    # random component: ACT rate floor over banks of all ranks
    act_spacing = max(t.tFAW / 4.0, t.tRRD) / cfg.org.ranks
    rnd_cost = max(t.tBL, act_spacing)
    cost = (1 - random_frac) * seq_cost + random_frac * rnd_cost
    return t.tBL / cost


@dataclasses.dataclass
class AnalyticalEstimate:
    runtime_ns: float
    iterations: int
    bytes_total: int
    bound: str                      # "pipeline" | "memory"


def estimate_hitgraph(
    g: Graph, problem: Problem, cfg: HitGraphConfig = HitGraphConfig(),
    iterations: Optional[int] = None, activity: float = 1.0,
    update_ratio: float = 0.5,
) -> AnalyticalEstimate:
    """HitGraph runtime: per iteration, scatter + gather over p partitions
    spread over ``n_pes`` channels.

    ``activity``: mean fraction of iterations' partitions active;
    ``update_ratio``: merged updates per edge (u/m, < 1 by merging and
    filtering).  Defaults model stationary problems; pass measured values
    (e.g. from a converged run) for non-stationary ones.
    """
    dram = cfg.dram_config()
    if iterations is None:
        iterations = 1 if problem.stationary else 10
    q = cfg.partition_elements
    p = len(partition_intervals(g.n, q))
    ratio = dram.clock_ghz / cfg.acc_ghz
    per_ch_peak = dram.peak_gbps / dram.channels

    vals_bytes = g.n * cfg.value_bytes * activity
    edge_bytes = g.m * cfg.edge_bytes * activity
    upd_bytes = g.m * update_ratio * cfg.update_bytes * activity
    # scatter: prefetch + edges + update writes; gather: prefetch +
    # update reads + value writes
    scatter_bytes = vals_bytes + edge_bytes + upd_bytes
    gather_bytes = vals_bytes + upd_bytes + vals_bytes * update_ratio
    frac = _achievable_fraction(dram, n_streams=3, random_frac=0.1)
    bw = per_ch_peak * frac * min(cfg.n_pes, p)

    mem_ns = (scatter_bytes + gather_bytes) / bw
    pipe_cycles = (g.m * activity / cfg.pipelines            # edge reads
                   + g.m * update_ratio * activity / cfg.pipelines)
    pipe_ns = pipe_cycles / min(cfg.n_pes, p) / cfg.acc_ghz
    per_iter = max(mem_ns, pipe_ns)
    return AnalyticalEstimate(
        runtime_ns=per_iter * iterations,
        iterations=iterations,
        bytes_total=int((scatter_bytes + gather_bytes) * iterations),
        bound="memory" if mem_ns >= pipe_ns else "pipeline",
    )


def estimate_accugraph(
    g: Graph, problem: Problem, cfg: AccuGraphConfig = AccuGraphConfig(),
    iterations: Optional[int] = None, stall_factor: float = 1.05,
    changed_ratio: float = 0.3,
) -> AnalyticalEstimate:
    dram = cfg.dram_config()
    if iterations is None:
        iterations = 1 if problem.stationary else 6
    q = cfg.partition_elements or g.n
    p = int(np.ceil(g.n / q))
    vb, pb, nb = cfg.value_bytes, cfg.pointer_bytes, cfg.neighbor_bytes

    prefetch = g.n * vb                                   # once per iter
    dst_vals = (g.n * p - g.n) * vb                       # BRAM-filtered
    pointers = (g.n + 1) * p * pb
    nbrs = g.m * nb
    writes = g.n * changed_ratio * vb
    total = prefetch + dst_vals + pointers + nbrs + writes
    frac = _achievable_fraction(dram, n_streams=4, random_frac=0.05)
    mem_ns = total / (dram.peak_gbps * frac)

    pipe_cycles = p * (g.n / cfg.vertex_pipelines)
    pipe_cycles = max(pipe_cycles,
                      g.m * stall_factor / cfg.edge_pipelines)
    pipe_ns = pipe_cycles / cfg.acc_ghz
    per_iter = max(mem_ns, pipe_ns)
    return AnalyticalEstimate(
        runtime_ns=per_iter * iterations,
        iterations=iterations,
        bytes_total=int(total * iterations),
        bound="memory" if mem_ns >= pipe_ns else "pipeline",
    )
