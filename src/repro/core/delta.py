"""Delta-CSR trace builders for the dynamic-graph update path.

Applying an :class:`~repro.graphs.updates.UpdateBatch` on the device is
a *structural* rewrite: every partition whose edge membership changed
gets its storage regions re-streamed by the host DMA engine (both paper
accelerators store an edge under its **source** partition — HitGraph's
dst-sorted per-partition edge lists, AccuGraph's per-source-interval
inverse-CSR blocks — so the rewritten set is the source partitions of
inserted and deleted edges).  Untouched partitions keep their bytes,
their pack-cache entries, and their on-chip residency.

The builders here emit that rewrite as one ``ep{e}_apply``
:class:`~repro.core.trace.SegmentedTrace` phase — sequential,
DRAM-bound line writes over only the touched partitions' regions in the
**new** model's layout — and expose the same regions as line ranges for
:func:`repro.core.cache.invalidate_lines` (host DMA bypasses the
on-chip hierarchy, so exactly these lines must be dropped).

Duck-typed on the model attributes: HitGraph-shaped models expose
``edge_base`` / ``m_k``, AccuGraph-shaped models ``ptr_base`` /
``nbr_base`` / ``parts``.  New accelerators joining the dynamic path
implement either surface or register their own region map.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.dram import CACHE_LINE_BYTES
from repro.core.trace import bulk_issue
from repro.graphs.formats import Graph
from repro.graphs.updates import UpdateBatch


def structural_partitions(batch: UpdateBatch, g_before: Graph,
                          q: int, p: int) -> np.ndarray:
    """Source partitions whose edge storage the batch rewrites (edges
    live under their source partition in both modelled accelerators).
    Deleting compacts, inserting appends — either way the partition's
    whole region re-streams."""
    srcs = [np.asarray(batch.insert_src, dtype=np.int64)]
    if batch.n_deleted:
        srcs.append(g_before.src[batch.delete_idx])
    vs = np.concatenate(srcs)
    if not len(vs):
        return np.empty(0, dtype=np.int64)
    ks = np.unique(vs // max(int(q), 1))
    return ks[ks < p]


def delta_regions(model, touched: np.ndarray
                  ) -> List[Tuple[int, int]]:
    """``(byte_start, nbytes)`` of every storage region the rewrite of
    ``touched`` partitions re-streams, in the model's (new) layout."""
    regions: List[Tuple[int, int]] = []
    if hasattr(model, "edge_base"):                  # HitGraph-shaped
        eb = model.cfg.edge_bytes
        for k in touched:
            regions.append((int(model.edge_base[k]),
                            int(model.m_k[k]) * eb))
    elif hasattr(model, "nbr_base"):                 # AccuGraph-shaped
        pb = model.cfg.pointer_bytes
        nb = model.cfg.neighbor_bytes
        for k in touched:
            regions.append((int(model.ptr_base[k]),
                            (model.g.n + 1) * pb))
            regions.append((int(model.nbr_base[k]),
                            model.parts.blocks[int(k)].m * nb))
    else:
        raise TypeError(
            f"model {type(model).__name__} exposes neither an edge_base "
            "(HitGraph-shaped) nor an nbr_base (AccuGraph-shaped) "
            "layout; register a delta region map for it")
    return regions


def _all_regions(model):
    """Every named allocation of the model's layout(s):
    ``name -> (byte_start, nbytes)``."""
    if hasattr(model, "layouts"):                    # per-channel layouts
        out = {}
        for lay in model.layouts:
            out.update(lay.regions())
        return out
    return model.layout.regions()


def _to_line_range(byte0: int, nbytes: int):
    first = byte0 // CACHE_LINE_BYTES
    last = (byte0 + nbytes - 1) // CACHE_LINE_BYTES
    return (first, last - first + 1)


def stale_line_ranges(model_old, model_new,
                      touched: np.ndarray) -> List[Tuple[int, int]]:
    """Old-layout cache-line ranges whose on-chip residency is stale
    after an epoch's layout rebuild: regions belonging to a touched
    partition, plus every region the rebuild moved or resized (region
    sizes track per-partition edge counts, so a touched partition shifts
    everything allocated after it on its channel).

    Invalidating the *old* ranges is sufficient: the allocator packs
    regions disjointly, so any new-layout range overlapping a surviving
    cached line belongs to a region that itself moved — which is in this
    set (see the dynamic-soundness property test)."""
    old = _all_regions(model_old)
    new = _all_regions(model_new)
    tset = {int(k) for k in np.asarray(touched).ravel()}
    ranges = []
    for name, (byte0, nbytes) in old.items():
        if nbytes <= 0:
            continue
        suffix = name.rsplit("_", 1)[-1]
        is_touched = suffix.isdigit() and int(suffix) in tset
        if is_touched or new.get(name) != (byte0, nbytes):
            ranges.append(_to_line_range(byte0, nbytes))
    return ranges


def delta_line_ranges(model, touched: np.ndarray
                      ) -> List[Tuple[int, int]]:
    """The same regions as ``(first_line, n_lines)`` cache-line ranges —
    the invalidation keys for :func:`repro.core.cache.invalidate_lines`."""
    return [_to_line_range(byte0, nbytes)
            for byte0, nbytes in delta_regions(model, touched)
            if nbytes > 0]


def delta_phase(model, epoch: int, touched: np.ndarray):
    """The ``ep{epoch}_apply`` phase: sequential line writes over the
    touched partitions' regions (DRAM-bound streaming DMA — back-to-back
    issue lower bounds, like the models' prefetch streams).  Returns a
    ``(name, line, is_write, issue)`` phase tuple, or ``None`` when the
    batch touches nothing."""
    spans = []
    for byte0, nbytes in delta_regions(model, touched):
        if nbytes <= 0:
            continue
        first = byte0 // CACHE_LINE_BYTES
        last = (byte0 + nbytes - 1) // CACHE_LINE_BYTES
        spans.append(np.arange(first, last + 1, dtype=np.int64))
    if not spans:
        return None
    lines = np.concatenate(spans)
    return (f"ep{epoch}_apply", lines,
            np.ones(len(lines), dtype=bool),
            bulk_issue(len(lines), 0))
