"""DRAM service-timing semantics (single source of truth).

This module defines the per-channel, in-order request service model used by

* the event-driven engine (``core/engine.py``) — incremental form,
* the vectorized JAX model (``core/vectorized.py``) — ``lax.scan`` form,
* the Pallas kernel (``kernels/dram_timing``) — fused per-bank form,

all of which must agree *bit-exactly* on integer cycle counts (property
tests enforce this).

Model (one memory channel; requests served in stream order):

Per bank ``b`` we track the open row, the time of the last ACT, and the
earliest next column command (``bank_avail``).  A request to row ``r`` is:

* row hit      (open_row == r):  col = max(issue, bank_avail)
* row empty    (open_row == -1): act = max(issue, bank_avail);
                                 col = act + tRCD
* row conflict (other row open): pre = max(issue, bank_avail,
                                           act_time + tRAS);
                                 act = pre + tRP; col = act + tRCD

After the column command, data is ready at ``col + tCL`` and occupies the
shared channel data bus for ``tBL`` cycles: ``finish = max(col + tCL,
bus_free) + tBL``.  Back-to-back column commands to one bank are spaced by
``tCCD = tBL`` (``bank_avail = col + tBL``).

Activates are additionally rate-limited per *rank* (rank = bank //
banks_per_rank): ``act >= last_act_rank + tRRD`` and ``act >=
fourth_last_act_rank + tFAW`` (four-activate window).  These are the
constraints that make row-missing (irregular) streams degrade relative to
sequential ones even with high bank-level parallelism — the phenomenon the
paper builds on.

Simplifications vs. Ramulator (documented per DESIGN.md): writes share read
timing (tCWL ~ tCL), no refresh, no command-bus contention, FCFS per
channel.  These affect all compared configurations identically; the paper's
model is likewise an approximation (its hypothesis is exactly that this
level of fidelity suffices).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.core.dram import DRAMConfig, DRAMTiming, CACHE_LINE_BYTES

ROW_HIT, ROW_EMPTY, ROW_CONFLICT = 0, 1, 2

# A value safely below any valid cycle but not overflow-prone.
NEG_INF = -(1 << 40)


@dataclasses.dataclass
class ChannelState:
    """Mutable per-channel timing state (incremental event-driven form).

    ``banks_per_rank`` defaults to ``n_banks`` (single rank).
    """

    timing: DRAMTiming
    n_banks: int
    banks_per_rank: int = 0
    open_row: np.ndarray = None          # int64[n_banks], -1 == empty
    act_time: np.ndarray = None          # int64[n_banks]
    bank_avail: np.ndarray = None        # int64[n_banks]
    bus_free: int = 0
    act_hist: np.ndarray = None          # int64[n_ranks, 4] circular
    act_ptr: np.ndarray = None           # int64[n_ranks]
    last_act_rank: np.ndarray = None     # int64[n_ranks]

    def __post_init__(self) -> None:
        if self.banks_per_rank == 0:
            self.banks_per_rank = self.n_banks
        n_ranks = self.n_banks // self.banks_per_rank
        if self.open_row is None:
            self.open_row = np.full(self.n_banks, -1, dtype=np.int64)
            self.act_time = np.full(self.n_banks, NEG_INF, dtype=np.int64)
            self.bank_avail = np.zeros(self.n_banks, dtype=np.int64)
            self.act_hist = np.full((n_ranks, 4), NEG_INF, dtype=np.int64)
            self.act_ptr = np.zeros(n_ranks, dtype=np.int64)
            self.last_act_rank = np.full(n_ranks, NEG_INF, dtype=np.int64)

    def _record_act(self, rank: int, act: int) -> None:
        ptr = self.act_ptr[rank]
        self.act_hist[rank, ptr] = act
        self.act_ptr[rank] = (ptr + 1) % 4
        self.last_act_rank[rank] = act

    def _act_floor(self, rank: int) -> int:
        """Earliest allowed next ACT on this rank (tRRD + tFAW)."""
        t = self.timing
        oldest = self.act_hist[rank, self.act_ptr[rank]]
        return max(self.last_act_rank[rank] + t.tRRD, oldest + t.tFAW)

    def serve(self, issue: int, bank: int, row: int) -> Tuple[int, int]:
        """Serve one request; returns (finish_cycle, row_kind)."""
        t = self.timing
        rank = bank // self.banks_per_rank
        if self.open_row[bank] == row:
            kind = ROW_HIT
            col = max(issue, self.bank_avail[bank])
        elif self.open_row[bank] == -1:
            kind = ROW_EMPTY
            act = max(issue, self.bank_avail[bank], self._act_floor(rank))
            col = act + t.tRCD
            self.act_time[bank] = act
            self.open_row[bank] = row
            self._record_act(rank, act)
        else:
            kind = ROW_CONFLICT
            pre = max(issue, self.bank_avail[bank],
                      self.act_time[bank] + t.tRAS)
            act = max(pre + t.tRP, self._act_floor(rank))
            col = act + t.tRCD
            self.act_time[bank] = act
            self.open_row[bank] = row
            self._record_act(rank, act)
        self.bank_avail[bank] = col + t.tBL
        finish = max(col + t.tCL, self.bus_free) + t.tBL
        self.bus_free = finish
        return int(finish), kind


def simulate_channel(
    issue: np.ndarray, bank: np.ndarray, row: np.ndarray, timing: DRAMTiming,
    n_banks: int, banks_per_rank: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Reference (python-loop) per-channel service. Returns (finish, kind)."""
    st = ChannelState(timing=timing, n_banks=n_banks,
                      banks_per_rank=banks_per_rank)
    n = len(issue)
    finish = np.zeros(n, dtype=np.int64)
    kind = np.zeros(n, dtype=np.int8)
    for i in range(n):
        finish[i], kind[i] = st.serve(int(issue[i]), int(bank[i]),
                                      int(row[i]))
    return finish, kind


@dataclasses.dataclass
class TraceResult:
    """Timing + statistics of one simulated trace."""

    cycles: int                      # makespan in memory-clock cycles
    ns: float
    total_requests: int
    total_bytes: int
    row_hits: int
    row_empty: int
    row_conflicts: int
    achieved_gbps: float
    peak_gbps: float
    per_channel_cycles: Dict[int, int]
    finish: np.ndarray | None = None

    @property
    def hit_rate(self) -> float:
        if self.total_requests == 0:
            return 0.0
        return self.row_hits / self.total_requests

    @property
    def bandwidth_fraction(self) -> float:
        if self.peak_gbps == 0:
            return 0.0
        return self.achieved_gbps / self.peak_gbps


def simulate_trace(
    line_addr: np.ndarray,
    issue: np.ndarray,
    cfg: DRAMConfig,
    keep_finish: bool = False,
) -> TraceResult:
    """Simulate a full trace (program order) on all channels of ``cfg``.

    ``line_addr`` are cache-line addresses; ``issue`` are issue-cycle lower
    bounds (memory clock).  Channels operate independently; the global
    makespan is the max over channels.
    """
    line_addr = np.asarray(line_addr, dtype=np.int64)
    issue = np.asarray(issue, dtype=np.int64)
    comps = cfg.decode_lines(line_addr)
    finish_all = np.zeros(len(line_addr), dtype=np.int64)
    hits = empt = conf = 0
    per_channel: Dict[int, int] = {}
    for c in range(cfg.channels):
        m = comps["channel"] == c
        if not m.any():
            per_channel[c] = 0
            continue
        fin, kind = simulate_channel(
            issue[m], comps["bank_in_channel"][m], comps["row"][m],
            cfg.timing, cfg.banks_per_channel, cfg.org.banks,
        )
        finish_all[m] = fin
        hits += int((kind == ROW_HIT).sum())
        empt += int((kind == ROW_EMPTY).sum())
        conf += int((kind == ROW_CONFLICT).sum())
        per_channel[c] = int(fin[-1])
    cycles = int(finish_all.max()) if len(finish_all) else 0
    ns = cycles / cfg.clock_ghz
    total_bytes = len(line_addr) * CACHE_LINE_BYTES
    gbps = (total_bytes / ns) if ns > 0 else 0.0
    return TraceResult(
        cycles=cycles,
        ns=ns,
        total_requests=len(line_addr),
        total_bytes=total_bytes,
        row_hits=hits,
        row_empty=empt,
        row_conflicts=conf,
        achieved_gbps=gbps,
        peak_gbps=cfg.peak_gbps,
        per_channel_cycles=per_channel,
        finish=finish_all if keep_finish else None,
    )
