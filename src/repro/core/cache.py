"""On-chip cache-hierarchy model: BRAM vertex caches + stream prefetchers.

The paper's accelerators differ most in what they keep *on chip*
(AccuGraph's vertex BRAM, HitGraph's prefetch units), yet the trace
models historically baked those effects invisibly into the builders.
This module makes the hierarchy an explicit, sweepable simulation layer
that sits **between the emitted request program and the DRAM backends**:

    trace model -> SegmentedTrace -> [cache filter] -> pack -> fused scan

* A :class:`CacheConfig` describes a direct-mapped / set-associative
  **vertex cache** (LRU per set) plus an optional **sequential stream
  prefetcher**.  It hangs off :class:`~repro.core.dram.DRAMConfig.cache`,
  so every accelerator x memory x backend combination gains the axis for
  free and the geometry-keyed pack cache stays sound
  (``DRAMConfig.geometry_key`` includes the cache dimension).
* The **cache** drops read requests that hit on chip *before packing* —
  hits never reach the DRAM model.  Writes bypass the cache (the traced
  writes are exactly the accelerators' explicit DRAM write-backs; a
  write-absorbing model would double-count the BRAM accumulation the
  trace builders already perform on chip).
* The **prefetcher** is a stream buffer over the post-cache miss stream:
  within a phase, read requests to consecutive cache lines form runs,
  and each run's requests beyond the head are fetched up to ``degree``
  requests ahead of demand (their DRAM issue lower bound moves back to
  the triggering demand's issue).  Addresses, program order, and hence
  row-buffer kinds are untouched — prefetch only shapes *when* a fetch
  may start, so a prefetched program's makespan is never worse than the
  unprefetched one.

Both halves depend only on line addresses, program order, and (for the
prefetcher) the timing-independent issue lower bounds — never on DRAM
timing parameters — so a filtered program replays against whole timing
grids exactly like an unfiltered one.

Two interchangeable, bit-identical lookup implementations mirror the
pack-path split: a vectorized NumPy reference (sets are independent, so
requests group into per-set lockstep columns and a short Python loop
runs dense ``[sets, ways]`` LRU steps) and a jitted ``lax.scan`` device
path over the same columns (``REPRO_CACHE_BACKEND=host|device``
overrides the platform heuristic).  ``tests/test_cache_model.py``
enforces the equivalence against an element-wise oracle.
"""

from __future__ import annotations

import dataclasses
import os
from typing import TYPE_CHECKING, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.trace import SegmentedTrace, Trace


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """One level of on-chip hierarchy in front of a DRAM device.

    ``lines``  capacity in 64 B cache lines (0 = no cache); ``sets`` =
               ``lines // ways``; a line maps to set ``line % sets``.
    ``ways``   associativity (1 = direct-mapped), LRU replacement.
    ``prefetch_degree``  sequential stream-buffer depth: reads covered by
               an ongoing consecutive-line run are issued up to this many
               requests ahead of demand (0 = off).

    ``lines=0, prefetch_degree=0`` is the identity — the filtered
    pipeline is bit-equal to no cache at all (property-tested).
    """

    lines: int = 0
    ways: int = 1
    prefetch_degree: int = 0
    #: display only — excluded from eq/hash so same-geometry configs
    #: under different names share pack-cache entries (geometry_key
    #: compares CacheConfigs)
    name: str = dataclasses.field(default="", compare=False)

    #: checked by the `cache-key-fields` analysis rule
    TIMING_ONLY_FIELDS = {
        "name": "display only — same-geometry configs under different "
                "names must share pack-cache entries",
    }

    def __post_init__(self) -> None:
        if self.lines < 0 or self.ways < 1 or self.prefetch_degree < 0:
            raise ValueError(f"invalid cache geometry: {self}")
        if self.lines % self.ways:
            raise ValueError(
                f"cache lines ({self.lines}) must divide evenly into "
                f"ways ({self.ways})")

    @property
    def sets(self) -> int:
        return self.lines // self.ways

    @property
    def capacity_bytes(self) -> int:
        return self.lines * 64

    @property
    def enabled(self) -> bool:
        return self.lines > 0 or self.prefetch_degree > 0

    def display_name(self) -> str:
        if self.name:
            return self.name
        if not self.enabled:
            return "none"
        parts = []
        if self.lines:
            parts.append(f"{self.capacity_bytes // 1024}KiB/{self.ways}w")
        if self.prefetch_degree:
            parts.append(f"pf{self.prefetch_degree}")
        return "+".join(parts)


@dataclasses.dataclass
class CacheStats:
    """Accumulated hierarchy statistics of one filtered stream."""

    lookups: int = 0        # read requests that probed the cache
    hits: int = 0           # reads served on chip (dropped before DRAM)
    prefetch_hits: int = 0  # reads covered by the stream buffer

    def merge(self, other: "CacheStats") -> None:
        self.lookups += other.lookups
        self.hits += other.hits
        self.prefetch_hits += other.prefetch_hits

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)


@dataclasses.dataclass
class CacheState:
    """Mutable lookup state: per-set tags (-1 = invalid) and LRU ages
    (a permutation of ``0..ways-1`` per set; 0 = most recent, the way
    with the largest age is the victim — untouched ways keep the largest
    ages, so empty ways fill before any valid line is evicted)."""

    tags: np.ndarray        # int64[sets, ways]
    age: np.ndarray         # int64[sets, ways]


def invalidate_lines(state: Optional[CacheState],
                     cache: Optional[CacheConfig],
                     line_ranges) -> int:
    """Drop every cached line falling inside any ``(first_line,
    n_lines)`` range — the dynamic-update hook: when the host rewrites a
    partition's structural regions (edge / pointer / neighbor arrays),
    the on-chip copies of exactly those lines are stale and must miss on
    next access, while every other partition's residency survives.

    Invalidated ways become the oldest in their set (they refill before
    any surviving line is evicted); surviving ways keep their relative
    recency, so ages stay a per-set permutation.  Returns the number of
    lines dropped.
    """
    if state is None or cache is None or not cache.sets:
        return 0
    sets, W = state.tags.shape
    lines = state.tags * sets + np.arange(sets, dtype=np.int64)[:, None]
    mask = np.zeros_like(state.tags, dtype=bool)
    for first, cnt in line_ranges:
        if cnt > 0:
            mask |= (lines >= first) & (lines < first + cnt)
    mask &= state.tags >= 0
    n = int(mask.sum())
    if n:
        state.tags[mask] = -1
        key = state.age + W * mask
        state.age = np.argsort(
            np.argsort(key, axis=1, kind="stable"), axis=1, kind="stable")
    return n


def effective(cache: Optional[CacheConfig]) -> Optional[CacheConfig]:
    """Normalize a cache selection: a disabled config means "no cache"
    (the single coercion point the backends and config plumbing share)."""
    return cache if cache is not None and cache.enabled else None


def init_state(cache: Optional[CacheConfig]) -> Optional[CacheState]:
    if cache is None or cache.sets == 0:
        return None
    S, W = cache.sets, cache.ways
    return CacheState(
        tags=np.full((S, W), -1, dtype=np.int64),
        age=np.broadcast_to(np.arange(W, dtype=np.int64),
                            (S, W)).copy())


def _bucket(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


def _auto_prefers_device() -> bool:
    """Mirror of the pack-path platform heuristic: the jitted lookup only
    pays off when there is a real host->device boundary; on the CPU
    backend the NumPy column loop wins."""
    env = os.environ.get("REPRO_CACHE_BACKEND")
    if env in ("device", "host"):
        return env == "device"
    return jax.default_backend() != "cpu"


def _columns(n_rows: int, row: np.ndarray, tag: np.ndarray):
    """Group a per-set-ordered read stream into lockstep columns: column
    ``t`` holds each set's ``t``-th access (sets are independent, so
    serving columns in order is exactly program order per set).  Rows
    are *compact* — callers pass only the touched sets, so a skewed
    stream costs touched x max-per-set, never sets x max-per-set."""
    from repro.core.trace import group_ranks
    counts = np.bincount(row, minlength=n_rows)
    slot = group_ranks(counts, row)
    L = int(counts.max()) if len(row) else 0
    tag_m = np.full((n_rows, L), -1, dtype=np.int64)
    valid_m = np.zeros((n_rows, L), dtype=bool)
    tag_m[row, slot] = tag
    valid_m[row, slot] = True
    return tag_m, valid_m, slot


def _lookup_numpy(tags: np.ndarray, age: np.ndarray, tag_m: np.ndarray,
                  valid_m: np.ndarray) -> np.ndarray:
    """The NumPy reference lookup: dense ``[rows, ways]`` LRU steps over
    the lockstep columns; ``tags``/``age`` (the touched sets' state) are
    updated in place."""
    S, W = tags.shape
    L = tag_m.shape[1]
    hit_m = np.zeros((S, L), dtype=bool)
    rows = np.arange(S, dtype=np.int64)
    for t in range(L):
        cur = tag_m[:, t]
        v = valid_m[:, t]
        match = (tags == cur[:, None]) & v[:, None]
        h = match.any(axis=1)
        hit_age = np.max(np.where(match, age, -1), axis=1)
        # on a hit, ways more recent than the touched one age by 1; on a
        # miss every way ages and the oldest (argmax — unique, ages are a
        # permutation) is replaced.  Ages stay a permutation either way.
        thresh = np.where(h, hit_age, W)
        tgt = np.where(h, match.argmax(axis=1), age.argmax(axis=1))
        age += (age < thresh[:, None]) & v[:, None]
        r = rows[v]
        age[r, tgt[r]] = 0
        tags[r, tgt[r]] = cur[r]
        hit_m[:, t] = h
    return hit_m


@jax.jit
def _lookup_scan(tag_cols, valid_cols, tags0, age0):
    """The jitted counterpart of :func:`_lookup_numpy`: one ``lax.scan``
    over the lockstep columns, carry = (tags, ages).  Bit-identical by
    construction (same dense step; argmax tie-breaks agree because ages
    are a per-set permutation and at most one way matches)."""
    W = tags0.shape[1]
    way_ids = jnp.arange(W, dtype=jnp.int32)

    def step(carry, x):
        tags, age = carry
        cur, v = x
        match = (tags == cur[:, None]) & v[:, None]
        h = match.any(axis=1)
        hit_age = jnp.max(jnp.where(match, age, -1), axis=1)
        thresh = jnp.where(h, hit_age, W)
        tgt = jnp.where(h, jnp.argmax(match, axis=1),
                        jnp.argmax(age, axis=1))
        age = age + ((age < thresh[:, None]) & v[:, None])
        upd = (tgt[:, None] == way_ids) & v[:, None]
        age = jnp.where(upd, 0, age)
        tags = jnp.where(upd, cur[:, None], tags)
        return (tags, age), h

    (tags, age), hits = jax.lax.scan(step, (tags0, age0),
                                     (tag_cols, valid_cols))
    return hits, tags, age


def _lookup_device(tags: np.ndarray, age: np.ndarray, tag_m: np.ndarray,
                   valid_m: np.ndarray):
    """Jitted lookup over the compact column matrices; returns the hit
    matrix and the updated (touched-set) state arrays.  Row and column
    counts are bucketed to powers of two so the jit cache stays
    logarithmic in both (padded rows carry no valid accesses and their
    state is discarded)."""
    U, L = tag_m.shape
    if int(tag_m.max()) >= 2**31 or int(tags.max()) >= 2**31:
        raise ValueError(
            "cache tags exceed the device lookup's int32 range; use the "
            "host backend for this program")
    W = tags.shape[1]
    U_pad, L_pad = _bucket(U), _bucket(L)
    tag_p = np.full((L_pad, U_pad), -1, dtype=np.int32)
    valid_p = np.zeros((L_pad, U_pad), dtype=bool)
    tag_p[:L, :U] = tag_m.T
    valid_p[:L, :U] = valid_m.T
    tags_p = np.full((U_pad, W), -1, dtype=np.int32)
    tags_p[:U] = tags
    age_p = np.broadcast_to(np.arange(W, dtype=np.int32),
                            (U_pad, W)).copy()
    age_p[:U] = age
    hits, tags_out, age_out = _lookup_scan(
        jnp.asarray(tag_p), jnp.asarray(valid_p),
        jnp.asarray(tags_p), jnp.asarray(age_p))
    return (np.asarray(hits)[:L, :U].T,
            np.asarray(tags_out)[:U].astype(np.int64),
            np.asarray(age_out)[:U].astype(np.int64))


def lookup_reads(state: CacheState, set_idx: np.ndarray, tag: np.ndarray,
                 backend: str = "auto") -> np.ndarray:
    """Serve a read stream (program order) through the cache; returns the
    per-request hit mask and updates ``state`` in place.

    Only the *touched* sets' state rows are gathered, served, and
    scattered back, so cost is bounded by (touched sets x max accesses
    per set), independent of the total set count — a hot-line-skewed
    stream cannot inflate the column matrices by the full geometry.

    ``backend``: ``"host"`` (NumPy reference), ``"device"`` (jitted
    scan), or ``"auto"`` (platform heuristic; host whenever tags exceed
    the device path's int32 range).
    """
    if len(set_idx) == 0:
        return np.zeros(0, dtype=bool)
    uniq, inv = np.unique(set_idx, return_inverse=True)
    tag_m, valid_m, slot = _columns(len(uniq), inv, tag)
    tags_sub = state.tags[uniq]
    age_sub = state.age[uniq]
    if backend == "auto":
        backend = "device" if _auto_prefers_device() else "host"
        if backend == "device" and (int(tag.max()) >= 2**31
                                    or int(tags_sub.max()) >= 2**31):
            backend = "host"
    if backend == "device":
        hit_m, tags_sub, age_sub = _lookup_device(
            tags_sub, age_sub, tag_m, valid_m)
    elif backend == "host":
        hit_m = _lookup_numpy(tags_sub, age_sub, tag_m, valid_m)
    else:
        raise ValueError(
            f"cache backend must be auto|host|device, got {backend!r}")
    state.tags[uniq] = tags_sub
    state.age[uniq] = age_sub
    return hit_m[inv, slot]


def _prefetch_issue(line: np.ndarray, is_write: np.ndarray,
                    issue: np.ndarray, degree: int
                    ) -> Tuple[np.ndarray, int]:
    """Stream-buffer issue shaping for one phase: within each run of
    consecutive-line reads, request ``i`` of the run may be fetched when
    demand reaches request ``i - degree`` (clamped to the run head, and
    never later than its own demand), so its issue lower bound becomes
    ``min(issue[i], issue[max(i - degree, head)])``.  Writes and
    non-covered reads are untouched.  Returns ``(new_issue,
    prefetch_hits)`` — a hit is any read covered by an ongoing run.
    """
    r = np.nonzero(~is_write)[0]
    if len(r) == 0 or degree <= 0:
        return issue, 0
    ln = line[r]
    start = np.empty(len(r), dtype=bool)
    start[0] = True
    np.not_equal(ln[1:], ln[:-1] + 1, out=start[1:])
    run_id = np.cumsum(start) - 1
    head = np.nonzero(start)[0][run_id]
    idx = np.arange(len(r), dtype=np.int64)
    src = np.maximum(idx - degree, head)
    out = issue.copy()
    out[r] = np.minimum(issue[r], issue[r[src]])
    return out, int((idx > head).sum())


def _filter_arrays(line, is_write, issue, cache: CacheConfig,
                   state: Optional[CacheState], backend: str):
    """One phase through the hierarchy: cache drop, then prefetch
    shaping.  Returns ``(line, is_write, issue, CacheStats)``."""
    stats = CacheStats()
    if cache.sets and len(line):
        r = np.nonzero(~is_write)[0]
        if len(r):
            lines_r = line[r]
            hit = lookup_reads(state, lines_r % cache.sets,
                               lines_r // cache.sets, backend)
            stats.lookups = len(r)
            stats.hits = int(hit.sum())
            keep = np.ones(len(line), dtype=bool)
            keep[r[hit]] = False
            line, is_write, issue = line[keep], is_write[keep], issue[keep]
    if cache.prefetch_degree and len(line):
        issue, ph = _prefetch_issue(line, is_write, issue,
                                    cache.prefetch_degree)
        stats.prefetch_hits = ph
    return line, is_write, issue, stats


def filter_trace(trace: "Trace", cache: Optional[CacheConfig],
                 state: Optional[CacheState] = None,
                 backend: str = "auto"):
    """Filter one phase trace; returns ``(trace, stats, state)`` (state
    is created on first use and chained across calls — the incremental
    counterpart of :func:`filter_program`)."""
    from repro.core.trace import Trace
    if cache is None or not cache.enabled:
        return trace, CacheStats(), state
    if state is None:
        state = init_state(cache)
    line, wr, iss, stats = _filter_arrays(
        trace.line_addr, trace.is_write, trace.issue, cache, state,
        backend)
    return Trace(line, wr, iss), stats, state


def filter_program(program: "SegmentedTrace",
                   cache: Optional[CacheConfig],
                   state: Optional[CacheState] = None,
                   backend: str = "auto"):
    """Filter a whole multi-phase program phase by phase with the cache
    state carried across phase barriers (the cache persists; prefetch
    runs never cross a barrier because issue cycles are phase-relative).
    Bit-equivalent to :func:`filter_trace` per phase.  Returns
    ``(program, stats, state)``; phases whose every request hits are
    dropped, matching the backends' empty-phase handling."""
    from repro.core.trace import SegmentedTrace
    if cache is None or not cache.enabled or len(program) == 0:
        return program, CacheStats(), state
    if state is None:
        state = init_state(cache)
    stats = CacheStats()
    phases = []
    for p in range(program.n_phases):
        s, e = int(program.offsets[p]), int(program.offsets[p + 1])
        line, wr, iss, ps = _filter_arrays(
            program.line_addr[s:e], program.is_write[s:e],
            program.issue[s:e], cache, state, backend)
        stats.merge(ps)
        phases.append((program.names[p], line, wr, iss))
    return SegmentedTrace.from_phases(phases), stats, state
