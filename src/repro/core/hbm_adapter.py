"""HBM access-pattern adapter: the paper's technique applied to the LM
training/serving framework (TPU side).

The paper's insight — performance of memory-bound workloads is predictable
from the off-chip request stream alone — applied to the dry-run roofline:
the §Roofline memory term uses *peak* HBM bandwidth; this adapter refines
it to an *achievable* bandwidth per dominant access pattern by generating
the pattern's line trace and running it through the same DRAM simulator
used for the graph accelerators (HBM2E device model, scaled to the chip's
aggregate bandwidth).

Patterns modelled (per architecture, extracted from the compiled HLO):

* ``stream``   — sequential weight/activation streaming (dense matmuls);
* ``gather``   — embedding-row gathers (vocab tables; rows of
  ``d_model * bytes``, random row order);
* ``kv_page``  — paged KV-cache reads during decode (page-sized runs at
  random page addresses);
* ``alltoall`` — MoE expert dispatch write bursts (expert-strided).

The resulting fractions feed ``launch/roofline.py`` as
``memory_term_effective = HLO_bytes / (chips * HBM_bw * fraction)``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import numpy as np

from repro.core.dram import DRAMConfig, hbm2e, CACHE_LINE_BYTES
from repro.core.hitgraph import CONTIGUOUS_ORDER
from repro.core.timing import simulate_trace
from repro.core.trace import Trace, bulk_issue


def tpu_hbm_config() -> DRAMConfig:
    """One v5e-class chip's HBM neighborhood: 16 HBM2E pseudo-channels,
    channel-interleaved addressing (the controller stripes consecutive
    lines across channels), peak 819 GB/s at cache-line granularity."""
    return hbm2e(channels=16)      # default (channel-first) interleave


def _run(lines: np.ndarray, cfg: DRAMConfig) -> float:
    tr = Trace(lines, np.zeros(len(lines), bool), bulk_issue(len(lines), 0))
    res = simulate_trace(tr.line_addr, tr.issue, cfg)
    return res.bandwidth_fraction


@functools.lru_cache(maxsize=None)
def pattern_fractions(n_lines: int = 16384, seed: int = 0) -> Dict[str, float]:
    """Achievable-bandwidth fraction per access pattern (cached)."""
    cfg = tpu_hbm_config()
    rng = np.random.default_rng(seed)
    total_lines = cfg.capacity_bytes // CACHE_LINE_BYTES
    out: Dict[str, float] = {}

    # sequential streaming
    out["stream"] = _run(np.arange(n_lines, dtype=np.int64), cfg)

    # embedding gather: random rows of 32 lines (2 KiB ~ d_model=1k bf16;
    # larger d_model streams even better, this is the conservative case)
    rows = rng.integers(0, total_lines // 32, n_lines // 32)
    emb = (rows[:, None] * 32
           + np.arange(32, dtype=np.int64)[None, :]).ravel()
    out["gather"] = _run(emb.astype(np.int64), cfg)

    # paged KV reads: 2 KiB pages (32 lines) at random page addresses
    pages = rng.integers(0, total_lines // 32, n_lines // 32)
    kv = (pages[:, None] * 32
          + np.arange(32, dtype=np.int64)[None, :]).ravel()
    out["kv_page"] = _run(kv.astype(np.int64), cfg)

    # MoE dispatch: expert-strided bursts of 64 lines (4 KiB chunks —
    # one token's d_model slab per expert buffer)
    experts = rng.integers(0, 64, max(n_lines // 64, 1))
    base = experts * (total_lines // 64)
    offs = rng.integers(0, total_lines // 64 - 64, len(experts))
    moe = ((base + offs)[:, None]
           + np.arange(64, dtype=np.int64)[None, :]).ravel()
    out["alltoall"] = _run(moe.astype(np.int64), cfg)
    return out


# Which pattern dominates the HLO bytes of each architecture family, used
# by the roofline report.  Mixes are (pattern -> weight) summing to 1.
ARCH_PATTERN_MIX: Dict[str, Dict[str, float]] = {
    "dense": {"stream": 0.92, "gather": 0.08},
    "moe": {"stream": 0.75, "alltoall": 0.20, "gather": 0.05},
    "hybrid": {"stream": 0.90, "gather": 0.10},
    "vlm": {"stream": 0.92, "gather": 0.08},
    "audio": {"stream": 0.95, "gather": 0.05},
    "ssm": {"stream": 0.95, "gather": 0.05},
    "decode": {"kv_page": 0.70, "stream": 0.30},
}


def effective_bandwidth_fraction(family: str, decode: bool = False) -> float:
    """Weighted achievable-bandwidth fraction for an arch family."""
    mix = ARCH_PATTERN_MIX["decode" if decode else family]
    fr = pattern_fractions()
    return float(sum(w * fr[p] for p, w in mix.items()))
