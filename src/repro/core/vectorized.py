"""Vectorized DRAM-timing model: ``lax.scan`` over per-channel streams.

Implements exactly the semantics of :mod:`repro.core.timing` (bit-exact on
integer cycles; property-tested) but as a JAX program:

* channels are independent -> packed to a ``[C, L]`` batch and ``vmap``-ed,
* each channel is an associative-state scan with carry
  ``(open_row[B], act_time[B], bank_avail[B], bus_free)``.

This is the TPU-native adaptation of the paper's hot loop: Ramulator ticks
one cycle at a time; we exploit the same structural property Ramulator's
state-machine tree encodes (banks evolve independently except for the
shared data bus, which is a running max) to turn the event loop into a
scan.  The Pallas kernel (``kernels/dram_timing``) fuses the same scan with
VMEM-resident state; this module is its jnp oracle *and* the fast path on
CPU.

Cycle math is int32 (TPU-friendly): traces must satisfy
``max_cycles < 2**31`` (asserted); large workloads are simulated in chunks
with carried state.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dram import DRAMConfig, CACHE_LINE_BYTES
from repro.core import timing as timing_mod
from repro.core.trace import Trace

NEG_INF32 = -(1 << 30)


@dataclasses.dataclass(frozen=True)
class PackedChannels:
    """Per-channel padded request streams + scatter metadata."""

    issue: np.ndarray        # int32[C, L]
    bank: np.ndarray         # int32[C, L]
    row: np.ndarray          # int32[C, L]
    valid: np.ndarray        # bool[C, L]
    scatter_index: np.ndarray  # int64[C, L] -> position in original trace


def pack_channels(trace: Trace, cfg: DRAMConfig) -> PackedChannels:
    """Split a program-order trace into per-channel padded streams."""
    comps = cfg.decode_lines(trace.line_addr)
    ch = comps["channel"]
    C = cfg.channels
    counts = np.bincount(ch, minlength=C)
    L = max(int(counts.max()) if len(trace) else 0, 1)
    issue = np.zeros((C, L), dtype=np.int32)
    bank = np.zeros((C, L), dtype=np.int32)
    row = np.zeros((C, L), dtype=np.int32)
    valid = np.zeros((C, L), dtype=bool)
    scatter = np.zeros((C, L), dtype=np.int64)
    if np.any(trace.issue < 0) or np.any(trace.issue >= 2**31 - 2**26):
        raise ValueError("issue cycles out of int32 range; chunk the trace")
    for c in range(C):
        idx = np.nonzero(ch == c)[0]
        n = len(idx)
        issue[c, :n] = trace.issue[idx]
        bank[c, :n] = comps["bank_in_channel"][idx]
        row[c, :n] = comps["row"][idx]
        valid[c, :n] = True
        scatter[c, :n] = idx
    return PackedChannels(issue, bank, row, valid, scatter)


def init_channel_carry(n_banks: int, banks_per_rank: int):
    """Initial scan carry for one channel (exposed for phase chaining)."""
    n_ranks = n_banks // banks_per_rank
    return (
        jnp.full((n_banks,), -1, dtype=jnp.int32),         # open_row
        jnp.full((n_banks,), NEG_INF32, dtype=jnp.int32),  # act_time
        jnp.zeros((n_banks,), dtype=jnp.int32),            # bank_avail
        jnp.zeros((), dtype=jnp.int32),                    # bus_free
        jnp.full((n_ranks, 4), NEG_INF32, dtype=jnp.int32),  # act_hist
        jnp.zeros((n_ranks,), dtype=jnp.int32),            # act_ptr
        jnp.full((n_ranks,), NEG_INF32, dtype=jnp.int32),  # last_act_rank
    )


def _channel_scan(
    issue: jnp.ndarray, bank: jnp.ndarray, row: jnp.ndarray,
    valid: jnp.ndarray, n_banks: int, banks_per_rank: int,
    tCL: int, tRCD: int, tRP: int, tRAS: int, tBL: int,
    tRRD: int, tFAW: int,
    carry=None,
):
    """Scan one channel's stream. Returns (finish[L], kind[L], carry)."""
    if carry is None:
        carry = init_channel_carry(n_banks, banks_per_rank)

    def step(state, x):
        (open_row, act_time, bank_avail, bus_free,
         act_hist, act_ptr, last_act_rank) = state
        iss, b, r, v = x
        rank = b // banks_per_rank
        o = open_row[b]
        av = bank_avail[b]
        at = act_time[b]
        hit = o == r
        empty = o == -1
        base = jnp.maximum(iss, av)
        # ACT rate limits per rank (tRRD, tFAW over the 4th-last ACT)
        ptr = act_ptr[rank]
        act_floor = jnp.maximum(last_act_rank[rank] + tRRD,
                                act_hist[rank, ptr] + tFAW)
        act = jnp.where(
            empty,
            jnp.maximum(base, act_floor),
            jnp.maximum(jnp.maximum(base, at + tRAS) + tRP, act_floor),
        )
        col = jnp.where(hit, base, act + tRCD)
        finish = jnp.maximum(col + tCL, bus_free) + tBL
        kind = jnp.where(hit, 0, jnp.where(empty, 1, 2)).astype(jnp.int8)
        did_act = jnp.logical_not(hit)
        new_state = (
            open_row.at[b].set(jnp.where(hit, o, r)),
            act_time.at[b].set(jnp.where(hit, at, act)),
            bank_avail.at[b].set(col + tBL),
            finish,
            act_hist.at[rank, ptr].set(
                jnp.where(did_act, act, act_hist[rank, ptr])),
            act_ptr.at[rank].set(
                jnp.where(did_act, (ptr + 1) % 4, ptr)),
            last_act_rank.at[rank].set(
                jnp.where(did_act, act, last_act_rank[rank])),
        )
        state = jax.tree.map(
            lambda new, old: jnp.where(v, new, old), new_state, state
        )
        out = (jnp.where(v, finish, jnp.int32(0)),
               jnp.where(v, kind, jnp.int8(-1)))
        return state, out

    carry, (finish, kind) = jax.lax.scan(
        step, carry, (issue, bank, row, valid)
    )
    return finish, kind, carry


@functools.partial(jax.jit, static_argnames=(
    "n_banks", "banks_per_rank", "tCL", "tRCD", "tRP", "tRAS", "tBL",
    "tRRD", "tFAW"))
def _simulate_packed(issue, bank, row, valid, n_banks, banks_per_rank,
                     tCL, tRCD, tRP, tRAS, tBL, tRRD, tFAW, carry=None):
    fn = functools.partial(
        _channel_scan, n_banks=n_banks, banks_per_rank=banks_per_rank,
        tCL=tCL, tRCD=tRCD, tRP=tRP, tRAS=tRAS, tBL=tBL, tRRD=tRRD,
        tFAW=tFAW,
    )
    if carry is None:
        finish, kind, carry = jax.vmap(
            lambda i, b, r, v: fn(i, b, r, v))(issue, bank, row, valid)
    else:
        finish, kind, carry = jax.vmap(
            lambda i, b, r, v, c: fn(i, b, r, v, carry=c))(
                issue, bank, row, valid, carry)
    return finish, kind, carry


def simulate_trace_jax(
    trace: Trace, cfg: DRAMConfig, keep_finish: bool = False,
) -> timing_mod.TraceResult:
    """Drop-in replacement for :func:`repro.core.timing.simulate_trace`."""
    if len(trace) == 0:
        return timing_mod.simulate_trace(trace.line_addr, trace.issue, cfg)
    packed = pack_channels(trace, cfg)
    t = cfg.timing
    finish, kind, _ = _simulate_packed(
        jnp.asarray(packed.issue), jnp.asarray(packed.bank),
        jnp.asarray(packed.row), jnp.asarray(packed.valid),
        cfg.banks_per_channel, cfg.org.banks,
        t.tCL, t.tRCD, t.tRP, t.tRAS, t.tBL, t.tRRD, t.tFAW,
    )
    finish = np.asarray(finish)
    kind = np.asarray(kind)
    v = packed.valid
    finish_flat = np.zeros(len(trace), dtype=np.int64)
    finish_flat[packed.scatter_index[v]] = finish[v]
    cycles = int(finish_flat.max())
    ns = cycles / cfg.clock_ghz
    total_bytes = len(trace) * CACHE_LINE_BYTES
    per_channel = {
        c: (int(finish[c][v[c]].max()) if v[c].any() else 0)
        for c in range(cfg.channels)
    }
    return timing_mod.TraceResult(
        cycles=cycles,
        ns=ns,
        total_requests=len(trace),
        total_bytes=total_bytes,
        row_hits=int((kind == 0).sum()),
        row_empty=int((kind == 1).sum()),
        row_conflicts=int((kind == 2).sum()),
        achieved_gbps=(total_bytes / ns) if ns > 0 else 0.0,
        peak_gbps=cfg.peak_gbps,
        per_channel_cycles=per_channel,
        finish=finish_flat if keep_finish else None,
    )
