"""Vectorized DRAM-timing model: ``lax.scan`` over per-channel streams.

Implements exactly the semantics of :mod:`repro.core.timing` (bit-exact on
integer cycles; property-tested) but as a JAX program:

* channels are independent -> packed to a ``[C, L]`` batch and ``vmap``-ed,
* each channel is an associative-state scan with carry
  ``(open_row[B], act_time[B], bank_avail[B], bus_free)``.

This is the TPU-native adaptation of the paper's hot loop: Ramulator ticks
one cycle at a time; we exploit the same structural property Ramulator's
state-machine tree encodes (banks evolve independently except for the
shared data bus, which is a running max) to turn the event loop into a
scan.  The Pallas kernel (``kernels/dram_timing``) fuses the same scan with
VMEM-resident state; this module is its jnp oracle *and* the fast path on
CPU.

Two entry points:

* :func:`simulate_packed` — one phase, channels ``vmap``-ed over a
  ``[C, L]`` batch (the legacy per-phase path);
* :func:`fused_scan` — a whole multi-phase program in one scan: channels
  step in lockstep over blocked ``[S, C, K]`` streams (a step retires up
  to K row hits per channel, or one miss) and phase barriers are honored
  *inside* the scan (the carry is re-based by the global makespan at
  each segment boundary), so an entire simulation run costs a handful of
  fixed-shape chunk dispatches instead of two dispatches per iteration.

DRAM timing parameters (``tCL``, ``tRCD``, ...) are *traced* int32 inputs,
not static jit arguments: one compiled scan serves DDR3 / DDR4 / HBM2 /
HBM2E, and the fused scan can be ``vmap``-ed over a batch of memory
configurations (see ``repro.sim.sweep(batch_memories=True)``).

Cycle math is int32 (TPU-friendly): each *phase* must satisfy
``max_cycles < 2**31`` (asserted); the fused scan re-bases at every
barrier, so whole runs of arbitrary length are fine.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import threading
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dram import DRAMConfig, DRAMTiming, CACHE_LINE_BYTES
from repro.core import timing as timing_mod
from repro.core.trace import Trace, group_ranks

NEG_INF32 = -(1 << 30)

#: per-phase relative issue cycles must stay below this (int32 headroom)
MAX_PHASE_ISSUE = 2**31 - 2**26

TIMING_FIELDS = ("tCL", "tRCD", "tRP", "tRAS", "tBL", "tRRD", "tFAW")

#: lanes per block in the fused scan (requests per channel per step);
#: hit-heavy programs use wide blocks, conflict-heavy ones serialize.
#: 8 is the measured sweet spot: the step's in-block chain resolution is
#: O(K^2), so wider blocks (16/32 were tried) shorten the scan less than
#: they fatten the step on these run-length distributions.
BLOCK_LANES = 8


def choose_block_lanes(n_miss: int, n: int) -> int:
    """Shared host/device block-width rule (exact integer threshold):
    hit-dominated streams (<1/2 misses) get 8 lanes, conflict-heavy ones
    serialize (almost every block would be a singleton miss anyway)."""
    return BLOCK_LANES if 2 * n_miss < n else 1

#: jitted-scan dispatch counters (see :func:`dispatch_counts`); the
#: throughput benchmark asserts a run costs a few fused chunk dispatches,
#: never the legacy two per iteration.  ``device_pack`` counts whole
#: device-resident pack invocations (each is two jitted dispatches:
#: classify+blocks, then the scatter).  ``pallas`` counts fused-serve
#: chunks dispatched through the Pallas kernel instead of the XLA scan.
DISPATCHES = {"packed": 0, "fused": 0, "fused_batch": 0,
              "device_pack": 0, "pallas": 0}

_DISPATCH_LOCK = threading.Lock()


def count_dispatch(kind: str, n: int = 1) -> None:
    """Thread-safe counter bump (the sweep engine serves independent
    batch groups from worker threads)."""
    with _DISPATCH_LOCK:
        DISPATCHES[kind] += n


def dispatch_counts() -> Dict[str, int]:
    with _DISPATCH_LOCK:
        return dict(DISPATCHES)


def reset_dispatch_counts() -> None:
    with _DISPATCH_LOCK:
        for k in DISPATCHES:
            DISPATCHES[k] = 0


#: legal values of the ``serve_backend`` knob (``DRAMConfig`` field /
#: ``simulate(serve_backend=...)``).  ``scan`` is the XLA ``lax.scan``
#: serve path; ``pallas`` the VMEM-resident kernel in
#: ``repro.kernels.dram_timing`` (bit-identical by construction: both
#: run :func:`make_serve_step`).
SERVE_BACKENDS = ("auto", "scan", "pallas")


def resolve_serve_backend(backend: str = "auto") -> str:
    """Resolve the ``serve_backend`` knob to ``scan`` or ``pallas``.

    ``auto`` prefers the Pallas kernel on accelerator platforms and the
    XLA scan on CPU, where the kernel could only run in interpret mode
    (an eval loop, orders of magnitude slower — fine for parity tests,
    wrong for serving).  ``REPRO_SERVE_BACKEND`` overrides ``auto``
    only; an explicit argument always wins.
    """
    if backend == "auto":
        env = os.environ.get("REPRO_SERVE_BACKEND", "")
        if env in ("scan", "pallas"):
            return env
        return "pallas" if jax.default_backend() != "cpu" else "scan"
    if backend not in ("scan", "pallas"):
        raise ValueError(
            f"serve_backend must be one of {SERVE_BACKENDS}, got "
            f"{backend!r}")
    return backend


def timing_params(t: DRAMTiming) -> np.ndarray:
    """Timing parameters as the traced int32[7] the scans consume."""
    return np.array([getattr(t, f) for f in TIMING_FIELDS], dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class PackedChannels:
    """Per-channel padded request streams + scatter metadata."""

    issue: np.ndarray        # int32[C, L]
    bank: np.ndarray         # int32[C, L]
    row: np.ndarray          # int32[C, L]
    valid: np.ndarray        # bool[C, L]
    scatter_index: np.ndarray  # int64[C, L] -> position in original trace


def pack_streams(ch: np.ndarray, issue: np.ndarray, bank: np.ndarray,
                 row: np.ndarray, channels: int, length: int):
    """Scatter program-order request components into padded per-channel
    streams (single stable argsort — the shared packing helper behind
    :func:`pack_channels` and the phase/fused backends in
    :mod:`repro.core.accel`).

    Returns ``(issue[C, L] int32, bank[C, L] int32, row[C, L] int32,
    valid[C, L] bool, slot[n] int64)`` where ``slot`` is each request's
    position within its channel stream.
    """
    counts = np.bincount(ch, minlength=channels)
    slot = group_ranks(counts, ch)
    issue_p = np.zeros((channels, length), dtype=np.int32)
    bank_p = np.zeros((channels, length), dtype=np.int32)
    row_p = np.zeros((channels, length), dtype=np.int32)
    valid_p = np.zeros((channels, length), dtype=bool)
    issue_p[ch, slot] = issue
    bank_p[ch, slot] = bank
    row_p[ch, slot] = row
    valid_p[ch, slot] = True
    return issue_p, bank_p, row_p, valid_p, slot


def pack_channels(trace: Trace, cfg: DRAMConfig) -> PackedChannels:
    """Split a program-order trace into per-channel padded streams."""
    comps = cfg.decode_lines(trace.line_addr)
    ch = comps["channel"]
    C = cfg.channels
    counts = np.bincount(ch, minlength=C)
    L = max(int(counts.max()) if len(trace) else 0, 1)
    if np.any(trace.issue < 0) or np.any(trace.issue >= MAX_PHASE_ISSUE):
        raise ValueError("issue cycles out of int32 range; chunk the trace")
    issue, bank, row, valid, slot = pack_streams(
        ch, trace.issue, comps["bank_in_channel"], comps["row"], C, L)
    scatter = np.zeros((C, L), dtype=np.int64)
    scatter[ch, slot] = np.arange(len(trace), dtype=np.int64)
    return PackedChannels(issue, bank, row, valid, scatter)


def init_channel_carry(n_banks: int, banks_per_rank: int):
    """Initial scan carry for one channel (exposed for phase chaining)."""
    n_ranks = n_banks // banks_per_rank
    return (
        jnp.full((n_banks,), -1, dtype=jnp.int32),         # open_row
        jnp.full((n_banks,), NEG_INF32, dtype=jnp.int32),  # act_time
        jnp.zeros((n_banks,), dtype=jnp.int32),            # bank_avail
        jnp.zeros((), dtype=jnp.int32),                    # bus_free
        jnp.full((n_ranks, 4), NEG_INF32, dtype=jnp.int32),  # act_hist
        jnp.zeros((n_ranks,), dtype=jnp.int32),            # act_ptr
        jnp.full((n_ranks,), NEG_INF32, dtype=jnp.int32),  # last_act_rank
    )


def rebase_carry(carry, shift):
    """Shift all time-like carry components ``shift`` cycles into the past,
    clamped at ``NEG_INF32`` (overflow-safe: computed as
    ``max(t, shift + NEG_INF32) - shift``).

    The service recurrence is shift-equivariant (every operation is a max
    or an add of a constant), and clamping only touches values that are
    already below any reachable future time, so a re-based scan is
    bit-equivalent to an absolute-time one — this is what lets the fused
    scan cross phase barriers without returning to Python and lets whole
    runs exceed the int32 cycle range.
    """
    (open_row, act_time, bank_avail, bus_free,
     act_hist, act_ptr, last_act_rank) = carry

    def sh(x):
        return jnp.maximum(x, shift + NEG_INF32) - shift

    return (open_row, sh(act_time), sh(bank_avail), sh(bus_free),
            sh(act_hist), act_ptr, sh(last_act_rank))


def _request_step(state, x, t):
    """Serve one request on one channel: the shared scan step.

    ``t`` is the 7-tuple of (traced) timing scalars in
    :data:`TIMING_FIELDS` order.  Invalid lanes (``v == False``) leave the
    state untouched and emit ``(0, -1)``.
    """
    tCL, tRCD, tRP, tRAS, tBL, tRRD, tFAW = t
    (open_row, act_time, bank_avail, bus_free,
     act_hist, act_ptr, last_act_rank) = state
    iss, b, r, v = x
    banks_per_rank = open_row.shape[0] // act_ptr.shape[0]
    rank = b // banks_per_rank
    o = open_row[b]
    av = bank_avail[b]
    at = act_time[b]
    hit = o == r
    empty = o == -1
    base = jnp.maximum(iss, av)
    # ACT rate limits per rank (tRRD, tFAW over the 4th-last ACT)
    ptr = act_ptr[rank]
    act_floor = jnp.maximum(last_act_rank[rank] + tRRD,
                            act_hist[rank, ptr] + tFAW)
    act = jnp.where(
        empty,
        jnp.maximum(base, act_floor),
        jnp.maximum(jnp.maximum(base, at + tRAS) + tRP, act_floor),
    )
    col = jnp.where(hit, base, act + tRCD)
    finish = jnp.maximum(col + tCL, bus_free) + tBL
    kind = jnp.where(hit, 0, jnp.where(empty, 1, 2)).astype(jnp.int8)
    did_act = jnp.logical_not(hit)
    new_state = (
        open_row.at[b].set(jnp.where(hit, o, r)),
        act_time.at[b].set(jnp.where(hit, at, act)),
        bank_avail.at[b].set(col + tBL),
        finish,
        act_hist.at[rank, ptr].set(
            jnp.where(did_act, act, act_hist[rank, ptr])),
        act_ptr.at[rank].set(
            jnp.where(did_act, (ptr + 1) % 4, ptr)),
        last_act_rank.at[rank].set(
            jnp.where(did_act, act, last_act_rank[rank])),
    )
    state = jax.tree.map(
        lambda new, old: jnp.where(v, new, old), new_state, state
    )
    out = (jnp.where(v, finish, jnp.int32(0)),
           jnp.where(v, kind, jnp.int8(-1)))
    return state, out


def _channel_scan(issue, bank, row, valid, t, carry):
    """Scan one channel's stream. Returns (finish[L], kind[L], carry)."""

    def step(state, x):
        return _request_step(state, x, t)

    carry, (finish, kind) = jax.lax.scan(
        step, carry, (issue, bank, row, valid)
    )
    return finish, kind, carry


@functools.partial(jax.jit, static_argnames=("n_banks", "banks_per_rank"))
def _simulate_packed(issue, bank, row, valid, timing, n_banks,
                     banks_per_rank, carry=None):
    t = tuple(timing[i] for i in range(len(TIMING_FIELDS)))
    if carry is None:
        single = init_channel_carry(n_banks, banks_per_rank)
        carry = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (issue.shape[0],) + x.shape),
            single)
    finish, kind, carry = jax.vmap(
        lambda i, b, r, v, c: _channel_scan(i, b, r, v, t, c))(
            issue, bank, row, valid, carry)
    return finish, kind, carry


def simulate_packed(issue, bank, row, valid, timing, n_banks,
                    banks_per_rank, carry=None):
    """Dispatch-counted wrapper around the jitted per-phase scan."""
    count_dispatch("packed")
    return _simulate_packed(
        jnp.asarray(issue), jnp.asarray(bank), jnp.asarray(row),
        jnp.asarray(valid), jnp.asarray(timing, dtype=jnp.int32),
        n_banks, banks_per_rank, carry)


# ---------------------------------------------------------------------------
# Fused whole-program scan: all phases of a run in one dispatch.
#
# The scan state deliberately avoids gathers/scatters (XLA CPU executes
# them ~10x slower than dense ops inside a scan): per-bank state is
# addressed with one-hot masks over the tiny [C, B] arrays, and the
# row-buffer *classification* (hit / empty / conflict) is precomputed on
# the host — it depends only on each bank's row sequence, never on timing
# — so the device scan only chains the max-plus timing recurrences.
# ---------------------------------------------------------------------------

def init_lean_carry(channels: int, n_banks: int, banks_per_rank: int):
    """Initial fused-scan carry: ``(avail[C,B], act[C,B], bus[C],
    act_hist[C,R,4], act_ptr[C,R])``.  ``last_act`` is not carried — it is
    always ``act_hist[ptr - 1]`` (the most recent push)."""
    n_ranks = n_banks // banks_per_rank
    C = channels
    return (
        jnp.zeros((C, n_banks), dtype=jnp.int32),             # bank_avail
        jnp.full((C, n_banks), NEG_INF32, dtype=jnp.int32),   # act_time
        jnp.zeros((C,), dtype=jnp.int32),                     # bus_free
        jnp.full((C, n_ranks, 4), NEG_INF32, dtype=jnp.int32),  # act_hist
        jnp.zeros((C, n_ranks), dtype=jnp.int32),             # act_ptr
    )


def lean_from_full(carry):
    """Convert a per-channel ``init_channel_carry`` pytree (leading C
    axis) to the fused-scan carry (drops ``open_row`` — host-tracked —
    and ``last_act_rank`` — derivable from the history)."""
    (open_row, act_time, bank_avail, bus_free,
     act_hist, act_ptr, last_act_rank) = carry
    return (bank_avail, act_time, bus_free, act_hist,
            act_ptr.astype(jnp.int32))


def full_from_lean(lean, open_row):
    """Inverse of :func:`lean_from_full`; ``open_row`` is the host-tracked
    int32[C, B] row state."""
    avail, act, bus, hist, ptr = lean
    last = jnp.take_along_axis(hist, ((ptr + 3) % 4)[..., None],
                               axis=2)[..., 0]
    return (jnp.asarray(open_row, dtype=jnp.int32), act, avail, bus,
            hist, ptr, last)


def _lean_rebase(avail, act, bus, hist, shift):
    def sh(x):
        return jnp.maximum(x, shift + NEG_INF32) - shift
    return sh(avail), sh(act), sh(bus), sh(hist)


#: bit layout of the packed per-request metadata word (``meta`` stream):
#: bits 0..7 bank-in-channel, 8 miss, 9 conflict, 10 valid,
#: 11..15 bank-rank within the block (for the in-step hit chain;
#: 5 bits covers BLOCK_LANES_WIDE - 1).
META_MISS, META_CONFL, META_VALID = 1 << 8, 1 << 9, 1 << 10
META_RB_SHIFT = 11
META_RB_MASK = 0x1F


def pack_meta(bank: np.ndarray, miss: np.ndarray, confl: np.ndarray,
              valid: np.ndarray, bank_rank=None) -> np.ndarray:
    """Fuse the per-request metadata into one int32 stream (one scan-step
    slice instead of four)."""
    meta = np.asarray(bank, dtype=np.int32).copy()
    meta |= np.asarray(miss, dtype=np.int32) << 8
    meta |= np.asarray(confl, dtype=np.int32) << 9
    meta |= np.asarray(valid, dtype=np.int32) << 10
    if bank_rank is not None:
        meta |= np.asarray(bank_rank, dtype=np.int32) << META_RB_SHIFT
    return meta


# ---------------------------------------------------------------------------
# Device-resident program packing: the whole pack path (address decode,
# row-kind classification, block decomposition, lockstep scatter) as two
# fixed-shape jitted dispatches, bit-identical to the NumPy packer in
# ``repro.core.accel.pack_program`` (the reference implementation).
#
# Shapes are bucketed: requests pad to the next power of two, phases to
# the next power of two, steps to the fused-scan chunk ladder — so the
# jit cache stays logarithmic in program size.  All transfers are int32
# (line addresses and issue cycles are range-checked on the host first),
# halving the host->device bytes of the int64 trace arrays; everything
# downstream of the transfer stays on the device.
# ---------------------------------------------------------------------------

def _decode_device(line, spec, banks):
    """Shift/mask decode of int32 line addresses on device (pow2 sizes
    only; mirrors ``DRAMConfig.decode_lines``)."""
    comps = {}
    for comp, shift, mask in spec:
        comps[comp] = (line >> shift) & mask
    comps["bank_in_channel"] = comps["rank"] * banks + comps["bank"]
    return comps


@functools.partial(jax.jit,
                   static_argnames=("spec", "C", "B", "banks"))
def _device_pack_core(line, issue, offsets, n, open_row, spec, C, B,
                      banks):
    """Classify + block-decompose a padded program on device.

    ``line``/``issue`` are int32[Npad] (padded past ``n``), ``offsets``
    int32[P_pad + 1] phase offsets (padded with the total length),
    ``open_row`` the int32[C, B] row state entering the program.  Returns
    the grouped-order streams the scatter stage consumes plus per-phase
    reductions — every array stays on device.
    """
    Npad = line.shape[0]
    P_pad = offsets.shape[0] - 1
    idx = jnp.arange(Npad, dtype=jnp.int32)
    valid = idx < n
    comps = _decode_device(line, spec, banks)
    ch = comps["channel"]
    bank_in_ch = comps["bank_in_channel"]
    row = comps["row"]
    bank_global = ch * B + bank_in_ch
    # ---- row-kind classification (mirrors classify_rows) --------------
    sort_key = jnp.where(valid, bank_global, C * B)
    order1 = jnp.argsort(sort_key, stable=True)
    gbo = sort_key[order1]
    rows_o = row[order1]
    valid_o = valid[order1]
    first = jnp.concatenate(
        [jnp.ones(1, bool), gbo[1:] != gbo[:-1]])
    last = jnp.concatenate([gbo[:-1] != gbo[1:], jnp.ones(1, bool)])
    open_flat = jnp.concatenate(
        [open_row.reshape(-1), jnp.full((1,), -1, jnp.int32)])
    prev = jnp.where(
        first, open_flat[gbo],
        jnp.concatenate([rows_o[:1], rows_o[:-1]]))
    kind_o = jnp.where(prev == rows_o, 0,
                       jnp.where(prev == -1, 1, 2)).astype(jnp.int8)
    kind_o = jnp.where(valid_o, kind_o, jnp.int8(0))
    kind = jnp.zeros(Npad, jnp.int8).at[order1].set(kind_o)
    open_out = open_row.reshape(-1).at[
        jnp.where(last & valid_o, gbo, C * B)
    ].set(rows_o, mode="drop").reshape(C, B)
    # ---- K selection (traced form of choose_block_lanes) --------------
    n_miss = jnp.sum(jnp.where(valid, kind != 0, False))
    K = jnp.where(2 * n_miss < n, BLOCK_LANES, 1).astype(jnp.int32)
    # ---- per-phase request ids + hit/conflict reductions --------------
    phase = (jnp.searchsorted(offsets, idx, side="right") - 1
             ).astype(jnp.int32)
    hits_p = jnp.zeros(P_pad, jnp.int32).at[phase].add(
        (kind == 0) & valid, mode="drop")
    confl_p = jnp.zeros(P_pad, jnp.int32).at[phase].add(
        (kind == 2) & valid, mode="drop")
    # ---- block decomposition within (phase, channel) streams ----------
    key = jnp.where(valid, phase * C + ch, P_pad * C)
    order2 = jnp.argsort(key, stable=True)
    key_s = key[order2]
    kind_s = kind[order2]
    miss_s = kind_s != 0
    valid_s = valid[order2]
    bank_s = bank_in_ch[order2]
    group_first = jnp.concatenate(
        [jnp.ones(1, bool), key_s[1:] != key_s[:-1]])
    prev_miss = jnp.concatenate([jnp.zeros(1, bool), miss_s[:-1]])
    run_start = group_first | miss_s | prev_miss
    run_id = jnp.cumsum(run_start.astype(jnp.int32)) - 1
    run_len = jnp.zeros(Npad, jnp.int32).at[run_id].add(1)
    run_off = jnp.cumsum(run_len) - run_len
    pos = idx - run_off[run_id]
    lane = pos % K
    bpr = (run_len + K - 1) // K
    block_off = jnp.cumsum(bpr) - bpr
    block_id = block_off[run_id] + pos // K
    # first block of the current group, propagated forward (block_id is
    # globally non-decreasing in grouped order)
    fb = jax.lax.cummax(jnp.where(group_first, block_id, -1))
    block_rank = block_id - fb
    # bank-rank within (block, bank): K-1 shifted comparisons; blocks
    # never span K lanes, so cross-block pairs compare unequal block ids
    # (which is also why running the widest static loop is K-safe)
    rb = jnp.zeros(Npad, jnp.int32)
    kb = block_id * B + bank_s
    for j in range(1, BLOCK_LANES):
        rb = rb + jnp.concatenate(
            [jnp.zeros(j, jnp.int32),
             (kb[j:] == kb[:-j]).astype(jnp.int32)])
    group_last = jnp.concatenate([group_first[1:], jnp.ones(1, bool)])
    n_blocks = jnp.zeros(P_pad * C, jnp.int32).at[
        jnp.where(group_last & valid_s, key_s, P_pad * C)
    ].set(block_rank + 1, mode="drop")
    L_p = n_blocks.reshape(P_pad, C).max(axis=1)
    step_starts = jnp.cumsum(L_p) - L_p
    S = L_p.sum()
    phase_s = jnp.minimum(key_s // C, P_pad - 1)
    r_idx = step_starts[phase_s] + block_rank
    issue_s = issue[order2]
    meta_s = (bank_s
              | (miss_s.astype(jnp.int32) << 8)
              | ((kind_s == 2).astype(jnp.int32) << 9)
              | (valid_s.astype(jnp.int32) << 10)
              | (rb << META_RB_SHIFT))
    return (r_idx, ch[order2], lane, issue_s, meta_s, valid_s,
            L_p, hits_p, confl_p, kind, open_out, S, K)


@functools.partial(jax.jit, static_argnames=("S_pad", "C", "K"))
def _device_pack_scatter(r_idx, c_idx, lane, issue_s, meta_s, valid_s,
                         L_p, S_pad, C, K):
    """Scatter the grouped streams into the blocked lockstep
    ``[S_pad, C, K]`` arrays + phase-boundary markers."""
    tgt = jnp.where(valid_s, r_idx, S_pad)
    issue = jnp.zeros((S_pad, C, K), jnp.int32).at[
        tgt, c_idx, lane].set(issue_s, mode="drop")
    meta = jnp.zeros((S_pad, C, K), jnp.int32).at[
        tgt, c_idx, lane].set(meta_s, mode="drop")
    boundary = jnp.zeros(S_pad, bool).at[
        jnp.cumsum(L_p) - 1].set(True, mode="drop")
    return issue, meta, boundary


@jax.jit
def _device_phase_durations(fin, L_p):
    """Per-phase makespans from fused-scan finishes: segmented max of the
    per-step maxima over the phase step ranges (the device counterpart of
    ``finalize_program``'s ``maximum.reduceat``)."""
    step_max = fin.max(axis=(1, 2))
    ends = jnp.cumsum(L_p)
    phase = jnp.searchsorted(
        ends, jnp.arange(fin.shape[0], dtype=jnp.int32), side="right")
    return jnp.zeros(L_p.shape[0], jnp.int32).at[phase].max(
        step_max, mode="drop")


def _fused_scan_core(issue, meta, boundary, timing, carry,
                     banks_per_rank):
    """One scan over a whole multi-phase program, K requests per channel
    per step.

    ``issue/meta`` are ``[S, C, K]`` *blocked* lockstep streams: step
    ``s`` serves every channel's ``s``-th block of the current phase.  A
    block is either up to K consecutive row *hits* (their only timing
    coupling is the per-bank ``bank_avail`` chain — a max-plus recurrence
    the step resolves with one in-step ``cummax`` over the block's
    bank-rank-adjusted issues — and the shared bus, another cummax) or a
    single row miss (which additionally touches the per-rank ACT
    history).  ``boundary[S]`` marks each phase's last step; at a
    boundary the global makespan (max over channels) re-bases the carry
    so the next phase's *phase-relative* issue cycles start from 0 again
    — the in-scan equivalent of the controller's "wait for all memory
    requests, then switch phases".

    The kernel is deliberately gather/scatter-free (XLA CPU executes
    those ~10x slower inside a scan): per-bank state is addressed with
    one-hot masks over the tiny [C, B] arrays.

    Returns ``(finish[S, C, K], carry)``; finishes are relative to their
    phase's start (0 on invalid lanes), so per-phase makespans and stats
    reduce on the host.
    """
    step = make_serve_step(timing, carry[0].shape[0], carry[0].shape[1],
                           carry[3].shape[1], issue.shape[2],
                           banks_per_rank)
    state, fin = jax.lax.scan(step, carry, (issue, meta, boundary))
    return fin, state


def make_serve_step(timing, C, B, R, K, banks_per_rank):
    """Build the blocked lockstep serve step over ``[C, K]`` request
    blocks — the single source of the step semantics, shared verbatim
    by the XLA scan (:func:`_fused_scan_core`) and the Pallas serve
    kernel (``repro.kernels.dram_timing``), so the two ``serve_backend``
    paths cannot drift.

    Returns ``step(state, (iss[C,K], mt[C,K], bnd)) -> (state,
    fin_out[C,K])`` where ``state`` is the 6-tuple in-scan carry
    (persistent lean carry + phase-makespan accumulator).  The
    phase-boundary carry re-base is branchless (``where`` on the
    boundary flag instead of ``lax.cond``): bit-identical, because a
    zero shift is the identity on every carry value (all are
    ``>= NEG_INF32`` by construction), and it is what lets the Pallas
    kernel run the same code without ref-mutating control flow.
    """
    tCL, tRCD, tRP, tRAS, tBL, tRRD, tFAW = (
        timing[i] for i in range(len(TIMING_FIELDS)))
    bank_ids = jnp.arange(B, dtype=jnp.int32)
    rank_ids = jnp.arange(R, dtype=jnp.int32)
    ptr_ids = jnp.arange(4, dtype=jnp.int32)
    lane_ids = jnp.arange(K, dtype=jnp.int32)
    lane_tbl = lane_ids * tBL                              # loop-invariant
    lane_tbl1 = (lane_ids + 1) * tBL

    tril = lane_ids[:, None] >= lane_ids[None, :]          # [K, K]

    def pick(masked, axis):
        return jnp.max(masked, axis=axis)

    def step(state, x):
        avail, act, bus, hist, ptr, pmf = state
        iss, mt, bnd = x                                   # [C, K]
        b = mt & 0xFF
        ms = (mt & META_MISS) != 0
        cf = (mt & META_CONFL) != 0
        v = (mt & META_VALID) != 0
        rb_tbl = ((mt >> META_RB_SHIFT) & META_RB_MASK) * tBL  # rank*tBL
        ohb = b[:, :, None] == bank_ids                    # [C, K, B]
        avail_b = pick(jnp.where(ohb, avail[:, None, :], NEG_INF32), 2)
        act_b = pick(jnp.where(ohb, act[:, None, :], NEG_INF32), 2)
        # --- hit chain: col_r = r*tBL + max(max_{s<=r, same bank}
        #     (iss_s - s*tBL), avail_entry) over the block's lanes.
        #     (Pairwise [K, K] mask; prefix-max reformulations via
        #     lax.cummax and an unrolled shift ladder were measured
        #     slower under XLA CPU at K=8.)
        adj = iss - rb_tbl
        same = (b[:, :, None] == b[:, None, :]) & tril     # [C, K, K]
        own = pick(jnp.where(same, adj[:, None, :], NEG_INF32), 2)
        col_hit = rb_tbl + jnp.maximum(own, avail_b)
        # --- miss machinery at block level (at most one miss per block,
        #     alone in it), so rank/ptr/hist select tiny [C, ...] slices
        mv = ms & v
        m_any = mv.any(axis=1)                             # [C]
        if R == 1:
            ptr_m = ptr[:, 0]                              # [C]
            hist_m = hist[:, 0]                            # [C, 4]
        else:
            rank = b // banks_per_rank
            rank_m = pick(jnp.where(mv, rank, 0), 1)       # [C]
            ohr_m = rank_m[:, None] == rank_ids            # [C, R]
            ptr_m = pick(jnp.where(ohr_m, ptr, 0), 1)
            hist_m = pick(jnp.where(ohr_m[:, :, None], hist, NEG_INF32),
                          1)                               # [C, 4]
        ohp_m = ptr_m[:, None] == ptr_ids                  # [C, 4]
        oh_last = ((ptr_m + 3) % 4)[:, None] == ptr_ids
        hist_p = pick(jnp.where(ohp_m, hist_m, NEG_INF32), 1)
        last_r = pick(jnp.where(oh_last, hist_m, NEG_INF32), 1)
        # ACT rate limits per rank (tRRD, tFAW over the 4th-last ACT)
        floor = jnp.maximum(last_r + tRRD, hist_p + tFAW)  # [C]
        base = jnp.maximum(iss, avail_b)
        pre = jnp.where(cf, jnp.maximum(base, act_b + tRAS) + tRP, base)
        a = jnp.maximum(pre, floor[:, None])               # miss ACT time
        col = jnp.where(ms, a + tRCD, col_hit)
        # --- shared data bus: prefix max over the block's lanes
        cadj = col + tCL - lane_tbl
        ccm = pick(jnp.where(tril & v[:, None, :], cadj[:, None, :],
                             NEG_INF32), 2)
        fin = lane_tbl1 + jnp.maximum(bus[:, None], ccm)
        fin_out = jnp.where(v, fin, jnp.int32(0))
        mx = pick(fin_out, 1)                              # [C]
        # bank_avail/act/hist/bus only ever increase (chains are
        # monotone), so updates are plain maxes — no masked selects
        bus = jnp.maximum(bus, mx)
        pmf = jnp.maximum(pmf, mx)
        vohb = ohb & v[:, :, None]
        avail = jnp.maximum(
            avail,
            pick(jnp.where(vohb, (col + tBL)[:, :, None], NEG_INF32), 1))
        a_m = pick(jnp.where(mv, a, NEG_INF32), 1)         # [C]
        act = jnp.maximum(
            act, pick(jnp.where(ohb & mv[:, :, None], a[:, :, None],
                                NEG_INF32), 1))
        if R == 1:
            hist = jnp.maximum(
                hist, jnp.where(ohp_m & m_any[:, None],
                                a_m[:, None], NEG_INF32)[:, None, :])
            ptr = jnp.where(m_any[:, None], (ptr_m + 1)[:, None] % 4,
                            ptr)
        else:
            hist = jnp.maximum(
                hist, jnp.where(
                    (ohr_m[:, :, None] & ohp_m[:, None, :])
                    & m_any[:, None, None],
                    a_m[:, None, None], NEG_INF32))
            ptr = jnp.where(ohr_m & m_any[:, None],
                            ((ptr_m + 1) % 4)[:, None], ptr)

        # branchless phase-boundary re-base: shift = 0 off-boundary is
        # the identity (every carry value is >= NEG_INF32)
        shift = jnp.where(bnd, jnp.max(pmf), jnp.int32(0))
        avail, act, bus, hist = _lean_rebase(avail, act, bus, hist,
                                             shift)
        pmf = jnp.where(bnd, jnp.zeros_like(pmf), pmf)
        return (avail, act, bus, hist, ptr, pmf), fin_out

    return step


def _concat_fins(fins, as_numpy, axis=0):
    """Join per-chunk finish arrays on the requested side of the
    host/device boundary (shared epilogue of the fused-scan wrappers)."""
    if len(fins) == 1:
        return fins[0]
    if as_numpy:
        return np.concatenate(fins, axis=axis)
    return jnp.concatenate(fins, axis=axis)


#: fixed scan-chunk sizes (steps).  A program runs as a few dispatches of
#: these two shapes instead of one dispatch of a bespoke shape: the scan
#: carry chains across chunks bit-exactly, and the jit cache holds TWO
#: compiled scans per DRAM structure for the life of the process — no
#: per-program-length recompilation.
CHUNK_LADDER = (1 << 13, 1 << 17)


def plan_chunks(n_steps: int):
    """Greedy chunk plan covering ``n_steps``: large chunks, then small
    ones (the tail pads to at most ``CHUNK_LADDER[0]`` wasted steps)."""
    small, large = CHUNK_LADDER
    n_large, rem = divmod(n_steps, large)
    n_small = -(-rem // small) if rem else 0
    return [large] * n_large + [small] * n_small


@jax.jit
def _fused_scan(issue, meta, boundary, timing, carry):
    banks_per_rank = carry[0].shape[1] // carry[3].shape[1]
    return _fused_scan_core(issue, meta, boundary, timing, carry,
                            banks_per_rank)


def fused_scan(issue, meta, boundary, timing, carry, as_numpy=True,
               backend="scan"):
    """Serve a whole packed program: a handful of fixed-shape jitted
    dispatches (see :data:`CHUNK_LADDER`), state chained across chunks.

    ``carry`` is the 5-tuple persistent lean carry; the transient
    phase-makespan accumulator is managed here (programs end on a phase
    boundary, where it is zero by construction).  ``as_numpy=False``
    keeps the finish array on device (the device-packed path reduces it
    there; nothing round-trips through the host).

    ``backend`` selects the serve implementation per
    :func:`resolve_serve_backend`: the XLA scan or the Pallas kernel
    (``repro.kernels.dram_timing.ops.dram_serve``) — bit-identical, both
    run :func:`make_serve_step`; the choice is purely an execution-speed
    knob.
    """
    backend = resolve_serve_backend(backend)
    if backend == "pallas":
        # lazy: ref.py in the kernel package imports this module
        from repro.kernels.dram_timing.ops import dram_serve
    C = issue.shape[1]
    state = tuple(carry) + (jnp.zeros((C,), dtype=jnp.int32),)
    timing = jnp.asarray(timing, dtype=jnp.int32)
    banks_per_rank = carry[0].shape[1] // carry[3].shape[1]
    fins = []
    pos = 0
    for size in plan_chunks(issue.shape[0]):
        chunk = (jnp.asarray(issue[pos:pos + size]),
                 jnp.asarray(meta[pos:pos + size]),
                 jnp.asarray(boundary[pos:pos + size]))
        if backend == "pallas":
            count_dispatch("pallas")
            fin, state = dram_serve(*chunk, timing, state,
                                    banks_per_rank=banks_per_rank)
        else:
            count_dispatch("fused")
            fin, state = _fused_scan(*chunk, timing, state)
        fins.append(np.asarray(fin) if as_numpy else fin)
        pos += size
    return _concat_fins(fins, as_numpy), state[:5]


@jax.jit
def _fused_scan_batch(issue, meta, boundary, timing, carry):
    banks_per_rank = carry[0].shape[2] // carry[3].shape[2]
    return jax.vmap(
        lambda i, mt, bd, tm, c: _fused_scan_core(
            i, mt, bd, tm, c, banks_per_rank)
    )(issue, meta, boundary, timing, carry)


@jax.jit
def _fused_scan_batch_shared(issue, meta, boundary, timing, carry):
    """Batch over timings/carries with the program streams SHARED
    (``in_axes=None``): every stream-only term of the step — the block
    masks and the O(K^2) hit-chain resolution — is computed once for the
    whole batch instead of per case, and the blocked arrays are never
    replicated M-fold."""
    banks_per_rank = carry[0].shape[2] // carry[3].shape[2]
    return jax.vmap(
        lambda tm, c: _fused_scan_core(issue, meta, boundary, tm, c,
                                       banks_per_rank),
        in_axes=(0, 0))(timing, carry)


def fused_scan_batch(issue, meta, boundary, timing, n_banks,
                     banks_per_rank, as_numpy=True):
    """Batched fused scan: leading axis = memory/case batch; each chunk
    dispatch serves every case in the batch
    (``sweep(batch_memories=True)``)."""
    M, S, C, K = issue.shape
    single = init_lean_carry(C, n_banks, banks_per_rank)
    state = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (M,) + x.shape),
        single + (jnp.zeros((C,), dtype=jnp.int32),))
    timing = jnp.asarray(timing, dtype=jnp.int32)
    fins = []
    pos = 0
    for size in plan_chunks(S):
        count_dispatch("fused_batch")
        fin, state = _fused_scan_batch(
            jnp.asarray(issue[:, pos:pos + size]),
            jnp.asarray(meta[:, pos:pos + size]),
            jnp.asarray(boundary[:, pos:pos + size]), timing, state)
        fins.append(np.asarray(fin) if as_numpy else fin)
        pos += size
    return _concat_fins(fins, as_numpy, axis=1), state[:5]


def fused_scan_batch_shared(issue, meta, boundary, timing, n_banks,
                            banks_per_rank, as_numpy=True):
    """Serve ONE packed program against a batch of timing vectors
    (``timing`` is int32[M, 7]) — the cache-hit fast path of
    ``sweep(batch_memories=True)`` on a geometry-shared memory grid.
    Returns ``(finish[M, S, C, K], states)`` like
    :func:`fused_scan_batch`, but the program streams are traced
    unbatched, so the stream-only step terms are case-invariant and the
    blocked arrays transfer once, not M times."""
    M = timing.shape[0]
    S, C, K = issue.shape
    single = init_lean_carry(C, n_banks, banks_per_rank)
    state = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (M,) + x.shape),
        single + (jnp.zeros((C,), dtype=jnp.int32),))
    timing = jnp.asarray(timing, dtype=jnp.int32)
    fins = []
    pos = 0
    for size in plan_chunks(S):
        count_dispatch("fused_batch")
        fin, state = _fused_scan_batch_shared(
            jnp.asarray(issue[pos:pos + size]),
            jnp.asarray(meta[pos:pos + size]),
            jnp.asarray(boundary[pos:pos + size]), timing, state)
        fins.append(np.asarray(fin) if as_numpy else fin)
        pos += size
    return _concat_fins(fins, as_numpy, axis=1), state[:5]


def simulate_trace_jax(
    trace: Trace, cfg: DRAMConfig, keep_finish: bool = False,
) -> timing_mod.TraceResult:
    """Drop-in replacement for :func:`repro.core.timing.simulate_trace`."""
    if len(trace) == 0:
        return timing_mod.simulate_trace(trace.line_addr, trace.issue, cfg)
    packed = pack_channels(trace, cfg)
    finish, kind, _ = simulate_packed(
        packed.issue, packed.bank, packed.row, packed.valid,
        timing_params(cfg.timing), cfg.banks_per_channel, cfg.org.banks,
    )
    finish = np.asarray(finish)
    kind = np.asarray(kind)
    v = packed.valid
    finish_flat = np.zeros(len(trace), dtype=np.int64)
    finish_flat[packed.scatter_index[v]] = finish[v]
    cycles = int(finish_flat.max())
    ns = cycles / cfg.clock_ghz
    total_bytes = len(trace) * CACHE_LINE_BYTES
    per_channel = {
        c: (int(finish[c][v[c]].max()) if v[c].any() else 0)
        for c in range(cfg.channels)
    }
    return timing_mod.TraceResult(
        cycles=cycles,
        ns=ns,
        total_requests=len(trace),
        total_bytes=total_bytes,
        row_hits=int((kind == 0).sum()),
        row_empty=int((kind == 1).sum()),
        row_conflicts=int((kind == 2).sum()),
        achieved_gbps=(total_bytes / ns) if ns > 0 else 0.0,
        peak_gbps=cfg.peak_gbps,
        per_channel_cycles=per_channel,
        finish=finish_flat if keep_finish else None,
    )
