"""DRAM device models: timing, organization, and address mapping.

Faithful to the paper's setup (Sect. 2.2, Tab. 2):

* HitGraph   -> DDR3, 4 channels, 2 ranks, speed grade 1600K, org 8Gb_x16
* AccuGraph  -> DDR4, 1 channel, 1 rank, speed grade 2400R, org 4Gb_x16
* Comparability -> DDR4, 1 channel, 1 rank, 2400R, 8Gb_x16
* HBM2/HBM2E -> the paper's "future work" DRAM types, used by the TPU/HBM
  adapter (``core/hbm_adapter.py``).

All requests are modelled at cache-line (64 B) granularity: DDR3/DDR4 return
64 B per request over 8 bursts (Sect. 2.2).  Timing parameters are expressed
in *memory-controller clock cycles* of the given speed grade.

The address mapping follows the paper's Fig. 5: a physical line address is
split LSB-to-MSB according to a configurable component order; the default
order ``("channel", "column", "rank", "bank", "row")`` interleaves
subsequent lines over channels first (the paper's example scheme).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, Optional, Sequence, Tuple

import numpy as np

if TYPE_CHECKING:  # pragma: no cover — annotation only, no runtime dep
    from repro.core.cache import CacheConfig

CACHE_LINE_BYTES = 64

AddressOrder = Tuple[str, ...]

DEFAULT_ORDER: AddressOrder = ("channel", "column", "rank", "bank", "row")

# Channel-as-MSB placement: each accelerator data structure lives whole in
# one channel (the paper's per-PE channel assignment).  Historically
# defined in ``core/hitgraph.py``; kept re-exported there.
CONTIGUOUS_ORDER: AddressOrder = ("column", "rank", "bank", "row", "channel")


@dataclasses.dataclass(frozen=True)
class DRAMTiming:
    """Timing parameters in memory-clock cycles.

    tCL   column (CAS) latency                  (row-buffer hit)
    tRCD  RAS-to-CAS delay                      (activate -> column cmd)
    tRP   precharge latency                     (row-buffer conflict)
    tRAS  minimum time between ACT and PRE of the same bank; the paper's
          "minimum latency between switching rows".
    tBL   data-bus occupancy per request (burst length 8 at DDR -> 4 clocks)
    tRRD  ACT-to-ACT, different banks, same rank
    tFAW  four-activate window per rank — together with tRRD this is what
          makes random (row-missing) streams degrade vs sequential ones,
          the paper's central phenomenon [Dr07].
    """

    tCL: int
    tRCD: int
    tRP: int
    tRAS: int
    tBL: int
    tRRD: int = 6
    tFAW: int = 32


@dataclasses.dataclass(frozen=True)
class DRAMOrganization:
    """Component counts of one memory *channel* (per Fig. 4)."""

    ranks: int
    banks: int            # banks per rank (bank groups folded in)
    rows: int             # rows per bank
    row_bytes: int        # bytes per row across the rank (columns x width)

    @property
    def lines_per_row(self) -> int:
        return self.row_bytes // CACHE_LINE_BYTES


@dataclasses.dataclass(frozen=True)
class DRAMConfig:
    """A complete memory-system model: standard, speed, organization,
    addressing — plus the optional on-chip hierarchy level in front of
    the device (:class:`repro.core.cache.CacheConfig`): requests that hit
    the cache are dropped before they reach the DRAM model."""

    name: str
    standard: str                     # DDR3 | DDR4 | HBM2 | HBM2E
    channels: int
    timing: DRAMTiming
    org: DRAMOrganization
    clock_ghz: float                  # memory-controller clock
    order: AddressOrder = DEFAULT_ORDER
    cache: Optional["CacheConfig"] = None
    #: serve-path execution backend: ``auto`` | ``scan`` | ``pallas``
    #: (see ``repro.core.vectorized.resolve_serve_backend``); ``auto``
    #: picks the Pallas kernel on TPU/GPU and the XLA scan on CPU.
    serve_backend: str = "auto"

    #: fields deliberately absent from structure_key/geometry_key:
    #: they change latency numbers, never the packed program geometry.
    #: (checked by the `cache-key-fields` analysis rule)
    TIMING_ONLY_FIELDS = {
        "name": "display label only",
        "standard": "display label; geometry lives in org/channels",
        "timing": "traced-scan input — packing never reads timings",
        "clock_ghz": "keyed separately by SimSession next to the "
                     "geometry key (timing-only scale factor)",
        "serve_backend": "execution-speed knob only — scan and pallas "
                         "serve bit-identical results, so configs "
                         "differing only here MUST share model/pack "
                         "cache entries",
    }

    def __post_init__(self):
        if self.serve_backend not in ("auto", "scan", "pallas"):
            raise ValueError(
                "serve_backend must be auto|scan|pallas, got "
                f"{self.serve_backend!r}")

    # ---- derived ----------------------------------------------------
    @property
    def banks_total(self) -> int:
        return self.channels * self.org.ranks * self.org.banks

    @property
    def banks_per_channel(self) -> int:
        return self.org.ranks * self.org.banks

    @property
    def peak_gbps(self) -> float:
        """Peak data bandwidth in GB/s over all channels."""
        lines_per_cycle = 1.0 / self.timing.tBL
        return (
            self.channels * lines_per_cycle * CACHE_LINE_BYTES * self.clock_ghz
        )

    @property
    def capacity_bytes(self) -> int:
        return (
            self.channels
            * self.org.ranks
            * self.org.banks
            * self.org.rows
            * self.org.row_bytes
        )

    def component_sizes(self) -> Dict[str, int]:
        return {
            "channel": self.channels,
            "column": self.org.lines_per_row,
            "rank": self.org.ranks,
            "bank": self.org.banks,
            "row": self.org.rows,
        }

    @property
    def effective_cache(self) -> Optional["CacheConfig"]:
        """The on-chip level actually in force (a disabled config counts
        as none) — what the DRAM backends consult."""
        c = self.cache
        return c if c is not None and c.enabled else None

    @property
    def structure_key(self):
        """The DRAM structure alone — channels, organization, address
        order.  This is all *trace emission* (model layouts, pacing,
        static streams) depends on: models with equal structure keys and
        clocks are shared across every cache and timing variant of a
        memory point."""
        return (self.channels, self.org, self.order)

    @property
    def geometry_key(self):
        """Everything request *packing* depends on — channel/rank/bank/row
        structure, the address-mapping order, and the on-chip cache level
        (cache hits are dropped before packing) — and nothing it does not
        (timing parameters are traced scan inputs, the clock only scales
        the report).  Devices with equal geometry keys share packed
        programs (see the sweep engine's pack cache)."""
        return (self.channels, self.org, self.order, self.cache)

    def decode_spec(self):
        """Static (shift, mask) per component for the pow2 shift/mask
        decode, as a hashable tuple ``((comp, shift, mask), ...)`` in
        address order — the jit-static description the device pack path
        consumes.  ``None`` when any component size is not a power of two
        (no real device; those fall back to the host packer)."""
        sizes = self.component_sizes()
        if any(s & (s - 1) for s in sizes.values()):
            return None
        spec = []
        shift = 0
        for comp in self.order:
            size = sizes[comp]
            spec.append((comp, shift, size - 1))
            shift += size.bit_length() - 1
        return tuple(spec)

    # ---- address mapping (Fig. 5) ------------------------------------
    def decode_lines(self, line_addrs: np.ndarray) -> Dict[str, np.ndarray]:
        """Split line addresses into DRAM components per the address order.

        Returns a dict with ``channel``, ``rank``, ``bank``, ``row``,
        ``column`` arrays plus ``bank_in_channel`` (rank*banks + bank) and
        ``bank_global``.
        """
        rem = np.asarray(line_addrs, dtype=np.int64)
        sizes = self.component_sizes()
        comps: Dict[str, np.ndarray] = {}
        pow2 = all(s & (s - 1) == 0 for s in sizes.values())
        for comp in self.order:
            size = sizes[comp]
            if pow2:            # shift/mask fast path (all real devices)
                comps[comp] = rem & (size - 1)
                rem = rem >> size.bit_length() - 1
            else:
                comps[comp] = rem % size
                rem = rem // size
        # Addresses beyond capacity wrap into higher rows (documented
        # simplification; traces are expected to fit).
        comps["row"] = comps["row"] + rem * 0
        comps["bank_in_channel"] = (
            comps["rank"] * self.org.banks + comps["bank"]
        )
        comps["bank_global"] = (
            comps["channel"] * self.banks_per_channel
            + comps["bank_in_channel"]
        )
        return comps

    def bytes_to_lines(self, byte_addrs: np.ndarray) -> np.ndarray:
        return np.asarray(byte_addrs, dtype=np.int64) // CACHE_LINE_BYTES


# ---------------------------------------------------------------------------
# Presets (Tab. 2 of the paper + HBM future-work configs)
# ---------------------------------------------------------------------------

def ddr3_1600k(channels: int = 4, ranks: int = 2) -> DRAMConfig:
    """DDR3-1600K (11-11-11), 8Gb x16 devices, 64-bit channel.

    Row size: 1024 columns x 16 bit x 4 devices = 8 KiB.
    Clock 800 MHz (1600 MT/s).
    """
    return DRAMConfig(
        name=f"DDR3_1600K_{channels}ch",
        standard="DDR3",
        channels=channels,
        timing=DRAMTiming(tCL=11, tRCD=11, tRP=11, tRAS=28, tBL=4,
                          tRRD=6, tFAW=40),
        org=DRAMOrganization(ranks=ranks, banks=8, rows=65536, row_bytes=8192),
        clock_ghz=0.8,
    )


def ddr4_2400r(channels: int = 1, ranks: int = 1,
               density: str = "4Gb") -> DRAMConfig:
    """DDR4-2400R (16-16-16), x16 devices, 64-bit channel.

    4Gb_x16: 32768 rows/bank (AccuGraph); 8Gb_x16: 65536 (Comparability).
    Clock 1200 MHz (2400 MT/s).  16 banks = 4 bank groups x 4 (folded).
    """
    rows = {"4Gb": 32768, "8Gb": 65536}[density]
    return DRAMConfig(
        name=f"DDR4_2400R_{density}_{channels}ch",
        standard="DDR4",
        channels=channels,
        timing=DRAMTiming(tCL=16, tRCD=16, tRP=16, tRAS=32, tBL=4,
                          tRRD=7, tFAW=36),
        org=DRAMOrganization(ranks=ranks, banks=16, rows=rows, row_bytes=8192),
        clock_ghz=1.2,
    )


def hbm2(channels: int = 8) -> DRAMConfig:
    """HBM2, 8 legacy channels (128-bit each), 2 Gb/s per pin.

    64 B = 4 beats on a 128-bit bus = 2 clocks at 1 GHz.  Per-channel row
    size 2 KiB, 16 banks.  This is the paper's §7 "future work" DRAM type
    and the base device model for the TPU HBM adapter.
    """
    return DRAMConfig(
        name=f"HBM2_{channels}ch",
        standard="HBM2",
        channels=channels,
        timing=DRAMTiming(tCL=14, tRCD=14, tRP=14, tRAS=34, tBL=2,
                          tRRD=2, tFAW=16),
        org=DRAMOrganization(ranks=1, banks=16, rows=16384, row_bytes=2048),
        clock_ghz=1.0,
    )


def hbm2e(channels: int = 16) -> DRAMConfig:
    """HBM2E-like stack: 16 pseudo-channels, 3.2 Gb/s/pin class.

    Used to model one TPU-v5e-class HBM stack neighborhood (819 GB/s with
    two stacks -> ~410 GB/s per stack; we expose channels so the adapter
    can scale to the chip's aggregate).
    """
    return DRAMConfig(
        name=f"HBM2E_{channels}ch",
        standard="HBM2E",
        channels=channels,
        timing=DRAMTiming(tCL=18, tRCD=18, tRP=18, tRAS=42, tBL=2,
                          tRRD=3, tFAW=20),
        org=DRAMOrganization(ranks=1, banks=16, rows=32768, row_bytes=1024),
        clock_ghz=1.6,
    )


PRESETS = {
    "hitgraph": lambda: ddr3_1600k(channels=4, ranks=2),
    "accugraph": lambda: ddr4_2400r(channels=1, ranks=1, density="4Gb"),
    "comparability": lambda: ddr4_2400r(channels=1, ranks=1, density="8Gb"),
    "hbm2": hbm2,
    "hbm2e": hbm2e,
}


# ---------------------------------------------------------------------------
# Memory layout helper: "data structures lie adjacent in memory as plain
# arrays" (Sect. 3.1).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MemoryLayout:
    """Sequential allocator of plain arrays, cache-line aligned."""

    base: int = 0
    _offsets: Dict[str, Tuple[int, int]] = dataclasses.field(
        default_factory=dict
    )
    _cursor: int = 0

    def __post_init__(self) -> None:
        self._cursor = self.base

    def allocate(self, name: str, nbytes: int) -> int:
        """Allocate ``nbytes`` for array ``name``; returns byte offset."""
        start = self._cursor
        self._offsets[name] = (start, nbytes)
        aligned = (nbytes + CACHE_LINE_BYTES - 1) // CACHE_LINE_BYTES
        self._cursor = start + aligned * CACHE_LINE_BYTES
        return start

    def offset(self, name: str) -> int:
        return self._offsets[name][0]

    def regions(self) -> Dict[str, Tuple[int, int]]:
        """Every allocation as ``name -> (byte_start, nbytes)`` — the
        surface the dynamic-update path diffs to find which regions an
        epoch's layout rebuild moved or resized."""
        return dict(self._offsets)

    def nbytes(self, name: str) -> int:
        return self._offsets[name][1]

    def element_lines(
        self, name: str, indices: np.ndarray, width_bytes: int
    ) -> np.ndarray:
        """Line addresses of elements ``indices`` of array ``name``."""
        byte_addrs = self.offset(name) + (
            np.asarray(indices, dtype=np.int64) * width_bytes
        )
        return byte_addrs // CACHE_LINE_BYTES

    def sequential_lines(
        self, name: str, count: int, width_bytes: int, start_elem: int = 0
    ) -> np.ndarray:
        """Unique line addresses touched by a sequential scan of ``count``
        elements, i.e. after perfect cache-line buffering (Fig. 6e)."""
        if count <= 0:
            return np.empty(0, dtype=np.int64)
        first = self.offset(name) + start_elem * width_bytes
        last = self.offset(name) + (start_elem + count) * width_bytes - 1
        return np.arange(
            first // CACHE_LINE_BYTES, last // CACHE_LINE_BYTES + 1,
            dtype=np.int64,
        )

    @property
    def total_bytes(self) -> int:
        return self._cursor - self.base
