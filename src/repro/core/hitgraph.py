"""HitGraph [Zh19] — edge-centric scatter/gather accelerator model.

Faithful to paper Sect. 3.2 / Fig. 7:

* p horizontal partitions (by source vertex), stored as dst-sorted edge
  lists; partitions statically assigned to memory channels, one PE per
  channel (4 channels, DDR3-1600K, 2 ranks, Tab. 2).
* Per iteration: **scatter** (prefetch partition values -> read edges
  rate-limited to 8 pipelines -> produce updates through a per-partition
  crossbar + cache-line buffers into per-partition update queues), then a
  phase barrier, then **gather** (prefetch values -> read update queues ->
  semi-random value writes through a cache-line buffer).
* Optimizations of the original system (all modelled): dst-sorted update
  *merging* (u < n x p), active-bitmap update *filtering*, and partition
  *skipping* (unchanged / no-update partitions).

Vectorized realization: per-iteration statistics come from the JAX
edge-centric engine; the whole run's request streams are emitted up front
by vectorized NumPy builders (segment-offset constructions over all
partitions at once — no per-partition or per-(k, j) Python loops, and the
per-iteration update merge is an adjacent-dedup over a once-sorted key
array instead of an ``np.unique`` sort) into one
:class:`~repro.core.trace.SegmentedTrace`, which is then *packed on the
device* (jitted decode/classify/block-decompose, int32-narrowed
transfers) and served by the fused DRAM scan in a handful of fixed-shape
dispatches with inter-phase barriers carried inside the scan.  The
emitted program depends on the DRAM device only through its geometry and
clock — never its timing — so the sweep engine replays one packed
program against whole timing-comparison grids.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.algorithms import edge_centric
from repro.algorithms.common import Problem, RunResult
from repro.core.accel import SimReport, VectorizedDRAM
from repro.core.dram import (CACHE_LINE_BYTES, CONTIGUOUS_ORDER, DRAMConfig,
                             MemoryLayout, ddr3_1600k)
from repro.core.trace import (SegmentedTrace, ragged_bulk, ragged_spans,
                              ragged_spread, span_counts)
from repro.graphs.formats import Graph, partition_intervals


@dataclasses.dataclass(frozen=True)
class HitGraphConfig:
    """Tab. 4 'HitGraph' row (reproducibility defaults)."""

    n_pes: int = 4                    # == memory channels
    pipelines: int = 8                # edges/cycle per PE
    partition_elements: int = 256_000  # q
    acc_ghz: float = 0.2
    edge_bytes: int = 8               # 64 bit/edge (paper Sect. 4.2)
    update_bytes: int = 8             # (dst, value)
    value_bytes: int = 4              # 32-bit values (Tab. 3)
    update_merging: bool = True
    update_filtering: bool = True
    partition_skipping: bool = True
    dram: Optional[DRAMConfig] = None

    def dram_config(self) -> DRAMConfig:
        if self.dram is not None:
            return self.dram
        base = ddr3_1600k(channels=self.n_pes, ranks=2)
        return dataclasses.replace(base, order=CONTIGUOUS_ORDER)


def _spread(n: int, start: int, end: int) -> np.ndarray:
    """Issue lower bounds spread uniformly over a producing window."""
    if n == 0:
        return np.empty(0, dtype=np.int64)
    if n == 1 or end <= start:
        return np.full(n, start, dtype=np.int64)
    return (start + (np.arange(n, dtype=np.float64) * (end - start) / n)
            ).astype(np.int64)


def _line_span(byte_start: int, nbytes: int) -> np.ndarray:
    """All lines of a sequential region (cache-line buffered)."""
    if nbytes <= 0:
        return np.empty(0, dtype=np.int64)
    first = byte_start // CACHE_LINE_BYTES
    last = (byte_start + nbytes - 1) // CACHE_LINE_BYTES
    return np.arange(first, last + 1, dtype=np.int64)


class HitGraphModel:
    """Builds the whole-run request program and simulates it."""

    def __init__(self, g: Graph, cfg: HitGraphConfig = HitGraphConfig()):
        self.cfg = cfg
        self.g = g.with_unit_weights() if g.weights is None else g
        self.dram = cfg.dram_config()
        q = cfg.partition_elements
        self.q = q
        self.intervals = partition_intervals(g.n, q)
        self.p = len(self.intervals)
        # partition-major, dst-sorted edge order: ONE stable argsort of
        # the composite (spart, dst) key — equivalent to the paper's
        # stable dst sort followed by a stable partition sort, and the
        # sorted key doubles as the update-merge key
        key = (self.g.src // q) * np.int64(g.n) + self.g.dst
        order = np.argsort(key, kind="stable")
        self.e_src = self.g.src[order]
        self.e_dst = self.g.dst[order]
        self.edge_key = key[order]                       # sorted
        self.e_spart = self.edge_key // g.n
        self.e_dpart = self.e_dst // q
        self.m_k = np.bincount(self.e_spart, minlength=self.p)
        self._layout()
        self._precompute_streams()

    # ------------------------------------------------------------------
    def _chan(self, k: int) -> int:
        return k % self.cfg.n_pes

    def _layout(self) -> None:
        """Per-channel contiguous arrays (channel = MSBs of the address)."""
        cfg, g = self.cfg, self.g
        cap_ch = self.dram.capacity_bytes // self.dram.channels
        self.layouts = [MemoryLayout(base=c * cap_ch)
                        for c in range(self.dram.channels)]
        self.val_base: List[int] = []
        self.edge_base: List[int] = []
        self.queue_base: List[int] = []
        in_counts = np.bincount(self.e_dpart, minlength=self.p)
        for k, (s, e) in enumerate(self.intervals):
            lay = self.layouts[self._chan(k)]
            n_k = e - s
            self.val_base.append(
                lay.allocate(f"values_{k}", n_k * cfg.value_bytes))
            self.edge_base.append(
                lay.allocate(f"edges_{k}",
                             int(self.m_k[k]) * cfg.edge_bytes))
            cap = int(min(in_counts[k], (n_k) * self.p)) + self.p
            self.queue_base.append(
                lay.allocate(f"queue_{k}", cap * cfg.update_bytes))
        for lay in self.layouts:
            if lay.total_bytes > cap_ch:
                raise ValueError(
                    "graph does not fit the per-channel capacity; use a "
                    "scaled dataset instance")

    def _precompute_streams(self) -> None:
        """Static per-partition stream extents (vectorized builders read
        these instead of re-deriving them every iteration)."""
        cfg = self.cfg
        starts = np.array([s for s, _ in self.intervals], dtype=np.int64)
        ends = np.array([e for _, e in self.intervals], dtype=np.int64)
        self._interval_start = starts
        self._val_base = np.asarray(self.val_base, dtype=np.int64)
        self._edge_base = np.asarray(self.edge_base, dtype=np.int64)
        self._queue_base = np.asarray(self.queue_base, dtype=np.int64)
        self._pre_first, self._pre_cnt = span_counts(
            self._val_base, (ends - starts) * cfg.value_bytes)
        self._edge_first, self._edge_cnt = span_counts(
            self._edge_base, self.m_k * cfg.edge_bytes)
        self._ratio = self.dram.clock_ghz / cfg.acc_ghz
        self._win = (np.ceil(self.m_k / cfg.pipelines)
                     * self._ratio).astype(np.int64)

    def _channel_cursor(self, w: np.ndarray) -> np.ndarray:
        """Exclusive per-channel cumulative PE cursor over partitions."""
        t0 = np.zeros(self.p, dtype=np.int64)
        for c in range(self.cfg.n_pes):
            sl = slice(c, None, self.cfg.n_pes)
            t0[sl] = np.cumsum(w[sl]) - w[sl]
        return t0

    # ------------------------------------------------------------------
    def _iteration_pairs(self, active: np.ndarray):
        """Merged updates per (src partition, dst): unique active pairs.

        ``O(m)`` per iteration: ``edge_key`` is sorted by construction,
        so this is a select + adjacent-dedup (replaces the per-iteration
        ``np.unique`` sort)."""
        if self.cfg.update_filtering:
            keys = self.edge_key[active[self.e_src]]
        else:
            keys = self.edge_key
        if self.cfg.update_merging and len(keys):
            keep = np.empty(len(keys), dtype=bool)
            keep[0] = True
            np.not_equal(keys[1:], keys[:-1], out=keep[1:])
            keys = keys[keep]
        k_part = keys // self.g.n
        dsts = keys % self.g.n
        return k_part, dsts

    def _scatter_phase(self, stationary: bool, active: np.ndarray,
                       u_count: np.ndarray, q_off: np.ndarray):
        """One iteration's scatter phase, all partitions vectorized."""
        cfg, p = self.cfg, self.p
        ub = cfg.update_bytes
        if cfg.partition_skipping and not stationary:
            proc = np.logical_or.reduceat(active, self._interval_start)
        else:
            proc = np.ones(p, dtype=bool)
        w = np.where(proc, np.maximum(self._win, 1), 0)
        t0 = self._channel_cursor(w)
        blk = p + 2                       # sub-stream id stride per k
        pk = np.nonzero(proc)[0]
        # 1. value prefetch (bulk, cache-line buffered)
        c0_lines = ragged_spans(self._pre_first[pk], self._pre_cnt[pk])
        c0_issue = ragged_bulk(t0[pk], self._pre_cnt[pk])
        c0_block = np.repeat(pk * blk, self._pre_cnt[pk])
        # 2. edge reads, rate-limited to `pipelines` edges/cycle
        c1_lines = ragged_spans(self._edge_first[pk], self._edge_cnt[pk])
        c1_issue = ragged_spread(t0[pk], self._win[pk], self._edge_cnt[pk])
        c1_block = np.repeat(pk * blk + 1, self._edge_cnt[pk])
        # 3. update writes through the crossbar to each queue j
        kk, jj = np.nonzero(u_count)      # row-major: k-major, j ascending
        sel = proc[kk]
        kk, jj = kk[sel], jj[sel]
        cnt = u_count[kk, jj]
        byte0 = self._queue_base[jj] + q_off[kk, jj] * ub
        w_first, w_cnt = span_counts(byte0, cnt * ub)
        c2_lines = ragged_spans(w_first, w_cnt)
        c2_issue = ragged_spread(t0[kk], self._win[kk], w_cnt)
        c2_block = np.repeat(kk * blk + 2 + jj, w_cnt)
        lines = np.concatenate([c0_lines, c1_lines, c2_lines])
        issue = np.concatenate([c0_issue, c1_issue, c2_issue])
        wr = np.zeros(len(lines), dtype=bool)
        wr[len(c0_lines) + len(c1_lines):] = True
        block = np.concatenate([c0_block, c1_block, c2_block])
        # PE-order concat, then the priority merge (stable sort by issue)
        order = np.argsort(block, kind="stable")
        order = order[np.argsort(issue[order], kind="stable")]
        return lines[order], wr[order], issue[order]

    def _gather_phase(self, changed: np.ndarray, dsts: np.ndarray,
                      dpart: np.ndarray, u_count: np.ndarray):
        """One iteration's gather phase, all partitions vectorized."""
        cfg, p = self.cfg, self.p
        ub, vb = cfg.update_bytes, cfg.value_bytes
        U = u_count.sum(axis=0)
        proc = (U > 0) if cfg.partition_skipping else np.ones(p, dtype=bool)
        win = (np.ceil(U / cfg.pipelines) * self._ratio).astype(np.int64)
        w = np.where(proc, np.maximum(win, 1), 0)
        t0 = self._channel_cursor(w)
        jk = np.nonzero(proc)[0]
        # 1. value prefetch
        c0_lines = ragged_spans(self._pre_first[jk], self._pre_cnt[jk])
        c0_issue = ragged_bulk(t0[jk], self._pre_cnt[jk])
        c0_block = np.repeat(jk * 3, self._pre_cnt[jk])
        # 2. update-queue reads, pipeline paced
        q_first, q_cnt = span_counts(self._queue_base, U * ub)
        c1_lines = ragged_spans(q_first[jk], q_cnt[jk])
        c1_issue = ragged_spread(t0[jk], win[jk], q_cnt[jk])
        c1_block = np.repeat(jk * 3 + 1, q_cnt[jk])
        # 3. semi-random value writes (changed only, line-buffered):
        #    per-partition unique lines via one lexsort + adjacent dedup
        sel = changed[dsts]
        jd, dd = dpart[sel], dsts[sel]
        line = (self._val_base[jd]
                + (dd - self._interval_start[jd]) * vb) // CACHE_LINE_BYTES
        order = np.lexsort((line, jd))
        jd, line = jd[order], line[order]
        if len(jd):
            keep = np.empty(len(jd), dtype=bool)
            keep[0] = True
            keep[1:] = (jd[1:] != jd[:-1]) | (line[1:] != line[:-1])
            jd, line = jd[keep], line[keep]
        w_cnt = np.bincount(jd, minlength=p)
        jp = np.nonzero(w_cnt)[0]
        c2_lines = line
        c2_issue = ragged_spread(t0[jp], win[jp], w_cnt[jp])
        c2_block = np.repeat(jp * 3 + 2, w_cnt[jp])
        lines = np.concatenate([c0_lines, c1_lines, c2_lines])
        issue = np.concatenate([c0_issue, c1_issue, c2_issue])
        wr = np.zeros(len(lines), dtype=bool)
        wr[len(c0_lines) + len(c1_lines):] = True
        block = np.concatenate([c0_block, c1_block, c2_block])
        order = np.argsort(block, kind="stable")
        order = order[np.argsort(issue[order], kind="stable")]
        return lines[order], wr[order], issue[order]

    # ------------------------------------------------------------------
    def build_program(self, problem: Problem,
                      run: RunResult) -> SegmentedTrace:
        """Emit every phase of the whole run up front as one segmented
        trace (scatter/gather per iteration, phase-relative issues)."""
        p = self.p
        phases = []
        for it, st in enumerate(run.per_iter):
            active = (st.active_before if not problem.stationary
                      else np.ones(self.g.n, dtype=bool))
            kp, dsts = self._iteration_pairs(active)
            dpart = dsts // self.q
            # updates grouped by (src part k, dst part j)
            u_count = np.bincount(
                kp * p + dpart, minlength=p * p).reshape(p, p)
            q_off = np.zeros((p, p), dtype=np.int64)
            q_off[1:] = np.cumsum(u_count, axis=0)[:-1]
            phases.append((f"it{it}_scatter", *self._scatter_phase(
                problem.stationary, active, u_count, q_off)))
            phases.append((f"it{it}_gather", *self._gather_phase(
                st.changed, dsts, dpart, u_count)))
        return SegmentedTrace.from_phases(phases)

    def make_report(self, problem: Problem, run: RunResult,
                    stats) -> SimReport:
        """Assemble the report from any executed DRAM-stats surface."""
        total_bytes = sum(ph.bytes for ph in stats.phases)
        return SimReport(
            system="hitgraph", problem=problem.value, graph=self.g.name,
            runtime_ns=stats.now / self.dram.clock_ghz,
            iterations=run.iterations, edges=self.g.m, vertices=self.g.n,
            total_requests=stats.total_requests, total_bytes=total_bytes,
            row_hit_rate=(stats.total_row_hits
                          / max(stats.total_requests, 1)),
            phases=stats.phases,
            cache_lookups=getattr(stats, "cache_lookups", 0),
            cache_hits=getattr(stats, "cache_hits", 0),
            prefetch_hits=getattr(stats, "prefetch_hits", 0),
        )

    def simulate(self, problem: Problem, root: int = 0,
                 fixed_iters: Optional[int] = None,
                 run: Optional[RunResult] = None,
                 memory_system=None) -> SimReport:
        """Simulate; ``memory_system`` injects a DRAM backend (any object
        with the :class:`VectorizedDRAM` program/phase interface, e.g.
        the event-driven ``repro.sim.backends.EventDRAM``)."""
        if run is None:
            run = edge_centric.run(self.g, problem, root=root,
                                   fixed_iters=fixed_iters)
        dram = (memory_system if memory_system is not None
                else VectorizedDRAM(self.dram))
        dram.run_program(self.build_program(problem, run))
        return self.make_report(problem, run, dram)


def simulate(g: Graph, problem: Problem,
             cfg: HitGraphConfig = HitGraphConfig(), root: int = 0,
             fixed_iters: Optional[int] = None) -> SimReport:
    """Deprecated shim — use :func:`repro.sim.simulate` with
    ``accelerator="hitgraph"`` (single entry point for all accelerators,
    memory types, and backends)."""
    from repro import sim
    return sim.simulate(sim.ScenarioSpec(
        g, problem, accelerator="hitgraph", config=cfg, root=root,
        fixed_iters=fixed_iters))
