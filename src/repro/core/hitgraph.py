"""HitGraph [Zh19] — edge-centric scatter/gather accelerator model.

Faithful to paper Sect. 3.2 / Fig. 7:

* p horizontal partitions (by source vertex), stored as dst-sorted edge
  lists; partitions statically assigned to memory channels, one PE per
  channel (4 channels, DDR3-1600K, 2 ranks, Tab. 2).
* Per iteration: **scatter** (prefetch partition values -> read edges
  rate-limited to 8 pipelines -> produce updates through a per-partition
  crossbar + cache-line buffers into per-partition update queues), then a
  phase barrier, then **gather** (prefetch values -> read update queues ->
  semi-random value writes through a cache-line buffer).
* Optimizations of the original system (all modelled): dst-sorted update
  *merging* (u < n x p), active-bitmap update *filtering*, and partition
  *skipping* (unchanged / no-update partitions).

Vectorized realization: per-iteration statistics come from the JAX
edge-centric engine; request streams are generated analytically with
issue-cycle lower bounds (bulk prefetches, rate-limited edge/update reads,
update/value writes spread over their producing window) and fed through
the carried-state DRAM scan with an inter-phase barrier.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.algorithms import edge_centric
from repro.algorithms.common import Problem, RunResult
from repro.core.accel import SimReport, VectorizedDRAM
from repro.core.dram import (CACHE_LINE_BYTES, CONTIGUOUS_ORDER, DRAMConfig,
                             MemoryLayout, ddr3_1600k)
from repro.core.trace import Trace, bulk_issue, interleave_issue_ordered
from repro.graphs.formats import Graph, partition_intervals


@dataclasses.dataclass(frozen=True)
class HitGraphConfig:
    """Tab. 4 'HitGraph' row (reproducibility defaults)."""

    n_pes: int = 4                    # == memory channels
    pipelines: int = 8                # edges/cycle per PE
    partition_elements: int = 256_000  # q
    acc_ghz: float = 0.2
    edge_bytes: int = 8               # 64 bit/edge (paper Sect. 4.2)
    update_bytes: int = 8             # (dst, value)
    value_bytes: int = 4              # 32-bit values (Tab. 3)
    update_merging: bool = True
    update_filtering: bool = True
    partition_skipping: bool = True
    dram: Optional[DRAMConfig] = None

    def dram_config(self) -> DRAMConfig:
        if self.dram is not None:
            return self.dram
        base = ddr3_1600k(channels=self.n_pes, ranks=2)
        return dataclasses.replace(base, order=CONTIGUOUS_ORDER)


def _spread(n: int, start: int, end: int) -> np.ndarray:
    """Issue lower bounds spread uniformly over a producing window."""
    if n == 0:
        return np.empty(0, dtype=np.int64)
    if n == 1 or end <= start:
        return np.full(n, start, dtype=np.int64)
    return (start + (np.arange(n, dtype=np.float64) * (end - start) / n)
            ).astype(np.int64)


def _line_span(byte_start: int, nbytes: int) -> np.ndarray:
    """All lines of a sequential region (cache-line buffered)."""
    if nbytes <= 0:
        return np.empty(0, dtype=np.int64)
    first = byte_start // CACHE_LINE_BYTES
    last = (byte_start + nbytes - 1) // CACHE_LINE_BYTES
    return np.arange(first, last + 1, dtype=np.int64)


class HitGraphModel:
    """Builds per-iteration traces and simulates them."""

    def __init__(self, g: Graph, cfg: HitGraphConfig = HitGraphConfig()):
        self.cfg = cfg
        self.g = g.with_unit_weights() if g.weights is None else g
        self.dram = cfg.dram_config()
        q = cfg.partition_elements
        self.q = q
        self.intervals = partition_intervals(g.n, q)
        self.p = len(self.intervals)
        # dst-sorted edge order; per-edge partition ids
        order = np.argsort(self.g.dst, kind="stable")
        self.e_src = self.g.src[order]
        self.e_dst = self.g.dst[order]
        self.e_spart = self.e_src // q
        self.e_dpart = self.e_dst // q
        part_order = np.argsort(self.e_spart, kind="stable")
        self.e_src = self.e_src[part_order]
        self.e_dst = self.e_dst[part_order]
        self.e_spart = self.e_spart[part_order]
        self.e_dpart = self.e_dpart[part_order]
        self.m_k = np.bincount(self.e_spart, minlength=self.p)
        self.edge_key = self.e_spart * g.n + self.e_dst  # merge key
        self._layout()

    # ------------------------------------------------------------------
    def _chan(self, k: int) -> int:
        return k % self.cfg.n_pes

    def _layout(self) -> None:
        """Per-channel contiguous arrays (channel = MSBs of the address)."""
        cfg, g = self.cfg, self.g
        cap_ch = self.dram.capacity_bytes // self.dram.channels
        self.layouts = [MemoryLayout(base=c * cap_ch)
                        for c in range(self.dram.channels)]
        self.val_base: List[int] = []
        self.edge_base: List[int] = []
        self.queue_base: List[int] = []
        in_counts = np.bincount(self.e_dpart, minlength=self.p)
        for k, (s, e) in enumerate(self.intervals):
            lay = self.layouts[self._chan(k)]
            n_k = e - s
            self.val_base.append(
                lay.allocate(f"values_{k}", n_k * cfg.value_bytes))
            self.edge_base.append(
                lay.allocate(f"edges_{k}",
                             int(self.m_k[k]) * cfg.edge_bytes))
            cap = int(min(in_counts[k], (n_k) * self.p)) + self.p
            self.queue_base.append(
                lay.allocate(f"queue_{k}", cap * cfg.update_bytes))
        for lay in self.layouts:
            if lay.total_bytes > cap_ch:
                raise ValueError(
                    "graph does not fit the per-channel capacity; use a "
                    "scaled dataset instance")

    # ------------------------------------------------------------------
    def _iteration_pairs(self, active: np.ndarray):
        """Merged updates per (src partition, dst): unique active pairs."""
        sel = active[self.e_src]
        if self.cfg.update_filtering:
            keys = self.edge_key[sel]
        else:
            keys = self.edge_key
        if self.cfg.update_merging:
            keys = np.unique(keys)
        else:
            keys = np.sort(keys, kind="stable")
        k_part = keys // self.g.n
        dsts = keys % self.g.n
        return k_part, dsts

    def simulate(self, problem: Problem, root: int = 0,
                 fixed_iters: Optional[int] = None,
                 run: Optional[RunResult] = None,
                 memory_system=None) -> SimReport:
        """Simulate; ``memory_system`` injects a DRAM backend (any object
        with the :class:`VectorizedDRAM` phase interface, e.g. the
        event-driven ``repro.sim.backends.EventDRAM``)."""
        cfg = self.cfg
        if run is None:
            run = edge_centric.run(self.g, problem, root=root,
                                   fixed_iters=fixed_iters)
        dram = (memory_system if memory_system is not None
                else VectorizedDRAM(self.dram))
        ratio = self.dram.clock_ghz / cfg.acc_ghz
        vb, eb, ub = cfg.value_bytes, cfg.edge_bytes, cfg.update_bytes

        for it, st in enumerate(run.per_iter):
            active = (st.active_before if not problem.stationary
                      else np.ones(self.g.n, dtype=bool))
            kp, dsts = self._iteration_pairs(active)
            dpart = dsts // self.q
            # updates grouped by (src part k, dst part j)
            u_count = np.zeros((self.p, self.p), dtype=np.int64)
            np.add.at(u_count, (kp, dpart), 1)
            q_off = np.zeros((self.p, self.p), dtype=np.int64)
            q_off[1:] = np.cumsum(u_count, axis=0)[:-1]  # offset into queue j

            # ---------------- scatter ---------------------------------
            scatter_traces: List[Trace] = []
            pe_cursor = np.zeros(cfg.n_pes, dtype=np.int64)
            part_active = np.array(
                [active[s:e].any() for (s, e) in self.intervals], dtype=bool)
            for k, (s, e) in enumerate(self.intervals):
                c = self._chan(k)
                skip = (cfg.partition_skipping and not problem.stationary
                        and not part_active[k])
                if skip:
                    continue
                t0 = int(pe_cursor[c])
                # 1. value prefetch (bulk, cache-line buffered)
                pre = _line_span(self.val_base[k], (e - s) * vb)
                scatter_traces.append(Trace(
                    pre, np.zeros(len(pre), bool), bulk_issue(len(pre), t0)))
                # 2. edge reads, rate-limited to `pipelines` edges/cycle
                m_k = int(self.m_k[k])
                elines = _line_span(self.edge_base[k], m_k * eb)
                window = int(np.ceil(m_k / cfg.pipelines) * ratio)
                scatter_traces.append(Trace(
                    elines, np.zeros(len(elines), bool),
                    _spread(len(elines), t0, t0 + window)))
                # 3. update writes through the crossbar to each queue j
                mask_k = kp == k
                dpart_k = dpart[mask_k]
                for j in np.unique(dpart_k):
                    cnt = int(u_count[k, j])
                    byte0 = (self.queue_base[j] + int(q_off[k, j]) * ub)
                    qlines = _line_span(byte0, cnt * ub)
                    scatter_traces.append(Trace(
                        qlines, np.ones(len(qlines), bool),
                        _spread(len(qlines), t0, t0 + window)))
                pe_cursor[c] = t0 + max(window, 1)
            dram.run_phase(interleave_issue_ordered(scatter_traces),
                           f"it{it}_scatter")

            # ---------------- gather ----------------------------------
            gather_traces = []
            pe_cursor[:] = 0
            for j, (s, e) in enumerate(self.intervals):
                c = self._chan(j)
                U_j = int(u_count[:, j].sum())
                if cfg.partition_skipping and U_j == 0:
                    continue
                t0 = int(pe_cursor[c])
                pre = _line_span(self.val_base[j], (e - s) * vb)
                gather_traces.append(Trace(
                    pre, np.zeros(len(pre), bool), bulk_issue(len(pre), t0)))
                qlines = _line_span(self.queue_base[j], U_j * ub)
                window = int(np.ceil(U_j / cfg.pipelines) * ratio)
                gather_traces.append(Trace(
                    qlines, np.zeros(len(qlines), bool),
                    _spread(len(qlines), t0, t0 + window)))
                # semi-random value writes (changed only, line-buffered
                # per dst-sorted queue region)
                mask_j = dpart == j
                wdst = dsts[mask_j]
                wdst = wdst[st.changed[wdst]]
                wlines = np.unique(
                    (self.val_base[j] + (wdst - s) * vb) // CACHE_LINE_BYTES)
                gather_traces.append(Trace(
                    wlines, np.ones(len(wlines), bool),
                    _spread(len(wlines), t0, t0 + window)))
                pe_cursor[c] = t0 + max(window, 1)
            dram.run_phase(interleave_issue_ordered(gather_traces),
                           f"it{it}_gather")

        total_bytes = sum(ph.bytes for ph in dram.phases)
        return SimReport(
            system="hitgraph", problem=problem.value, graph=self.g.name,
            runtime_ns=dram.now / self.dram.clock_ghz,
            iterations=run.iterations, edges=self.g.m, vertices=self.g.n,
            total_requests=dram.total_requests, total_bytes=total_bytes,
            row_hit_rate=(dram.total_row_hits / max(dram.total_requests, 1)),
            phases=dram.phases,
        )


def simulate(g: Graph, problem: Problem,
             cfg: HitGraphConfig = HitGraphConfig(), root: int = 0,
             fixed_iters: Optional[int] = None) -> SimReport:
    """Deprecated shim — use :func:`repro.sim.simulate` with
    ``accelerator="hitgraph"`` (single entry point for all accelerators,
    memory types, and backends)."""
    from repro import sim
    return sim.simulate(g, problem, accelerator="hitgraph", config=cfg,
                        root=root, fixed_iters=fixed_iters)
