"""Rapid accelerator-prototyping studies (paper Sect. 5).

The paper's engineering claim: new accelerator ideas can be evaluated in
the simulation environment instead of RTL.  This module packages that
workflow: enumerate design variants, simulate each, and report speedups
over the baseline — used by ``benchmarks/fig13_optimizations.py`` and the
``examples/graph_accelerator_study.py`` driver.

Variants (paper's two enhancements + beyond-paper ones we propose):

* ``prefetch_skip``  — skip re-prefetching a partition already in BRAM.
* ``partition_skip`` — dirty-bit partition skipping (exact; Sect. 5).
* ``both``           — combined.
* ``hbm``            — beyond-paper: swap DDR4 for an HBM2 stack (the
  paper's §7 future work), same accelerator logic.
* ``wide_prefetch``  — beyond-paper: issue prefetch at full bus burst
  (models a wider prefetch port; isolates the prefetch-bandwidth term).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.algorithms.common import Problem
from repro.core import accugraph
from repro.core.accel import SimReport
from repro.core.dram import hbm2
from repro.core.hitgraph import CONTIGUOUS_ORDER
from repro.graphs.formats import Graph


@dataclasses.dataclass
class StudyResult:
    variant: str
    report: SimReport
    speedup: float


def accugraph_variants(
    base: accugraph.AccuGraphConfig = accugraph.AccuGraphConfig(),
) -> Dict[str, accugraph.AccuGraphConfig]:
    return {
        "baseline": base,
        "prefetch_skip": dataclasses.replace(base, prefetch_skipping=True),
        "partition_skip": dataclasses.replace(base, partition_skipping=True),
        "both": dataclasses.replace(
            base, prefetch_skipping=True, partition_skipping=True),
        # HBM needs channel-interleaved placement: with the contiguous
        # (channel-as-MSB) layout the whole working set lands in one of 8
        # channels and HBM *loses* to DDR4 — the [Gh19]-style
        # workload/DRAM interaction the paper's §7 anticipates.
        "hbm": dataclasses.replace(base, dram=hbm2()),
    }


def run_study(
    g: Graph,
    problem: Problem,
    base: accugraph.AccuGraphConfig = accugraph.AccuGraphConfig(),
    root: int = 0,
    fixed_iters: Optional[int] = None,
    variants: Optional[List[str]] = None,
) -> List[StudyResult]:
    """Simulate all variants; speedup = baseline_runtime / variant_runtime.

    Partition skipping is definitionally inapplicable to stationary
    problems (PR/SpMV) — the paper notes PR "is not shown, since no
    partitions can be skipped"; we keep the variant but it degenerates to
    the baseline execution.
    """
    cfgs = accugraph_variants(base)
    names = variants if variants is not None else list(cfgs)
    baseline = accugraph.simulate(g, problem, cfgs["baseline"], root=root,
                                  fixed_iters=fixed_iters)
    out = [StudyResult("baseline", baseline, 1.0)]
    for name in names:
        if name == "baseline":
            continue
        rep = accugraph.simulate(g, problem, cfgs[name], root=root,
                                 fixed_iters=fixed_iters)
        out.append(StudyResult(
            name, rep, baseline.runtime_ns / max(rep.runtime_ns, 1e-9)))
    return out
