"""Request-trace container and builders.

A :class:`Trace` is the vectorized counterpart of the paper's request
streams: arrays of cache-line addresses + write flags + issue-cycle lower
bounds, in *program order*.  Accelerator models (``core/hitgraph.py``,
``core/accugraph.py``) build traces from per-iteration algorithm statistics
and feed them to ``core/vectorized.py`` / ``kernels/dram_timing``.

Issue-cycle lower bounds encode producer rate limits and phase barriers
(control flow): e.g. an edge reader rate-limited to 8 edges/cycle at
f_acc produces line ``i`` no earlier than ``start + i*lines_per_cycle``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence

import numpy as np

from repro.core.dram import CACHE_LINE_BYTES


@dataclasses.dataclass
class Trace:
    """A request trace in program order (cache-line granularity)."""

    line_addr: np.ndarray          # int64[n]
    is_write: np.ndarray           # bool[n]
    issue: np.ndarray              # int64[n], memory-clock cycles

    def __post_init__(self) -> None:
        self.line_addr = np.asarray(self.line_addr, dtype=np.int64)
        self.is_write = np.asarray(self.is_write, dtype=bool)
        self.issue = np.asarray(self.issue, dtype=np.int64)
        assert len(self.line_addr) == len(self.is_write) == len(self.issue)

    def __len__(self) -> int:
        return len(self.line_addr)

    @property
    def total_bytes(self) -> int:
        return len(self) * CACHE_LINE_BYTES

    @staticmethod
    def empty() -> "Trace":
        z = np.empty(0, dtype=np.int64)
        return Trace(z, z.astype(bool), z)

    @staticmethod
    def concat(traces: Sequence["Trace"]) -> "Trace":
        traces = [t for t in traces if len(t)]
        if not traces:
            return Trace.empty()
        return Trace(
            np.concatenate([t.line_addr for t in traces]),
            np.concatenate([t.is_write for t in traces]),
            np.concatenate([t.issue for t in traces]),
        )


@dataclasses.dataclass
class SegmentedTrace:
    """A whole-run request program: concatenated phase traces plus phase
    boundary markers.

    This is the unit the fused DRAM pipeline consumes: ``offsets[p] ..
    offsets[p+1]`` delimit phase ``p`` (program order within a phase;
    phases separated by barriers), ``issue`` is *phase-relative* (each
    phase restarts at cycle 0; the DRAM backend adds the running makespan
    at the barrier).  Empty phases are dropped at construction, matching
    the per-phase backends' early return.
    """

    line_addr: np.ndarray          # int64[N]
    is_write: np.ndarray           # bool[N]
    issue: np.ndarray              # int64[N], phase-relative memory cycles
    offsets: np.ndarray            # int64[P+1], phase p = [offsets[p], offsets[p+1])
    names: List[str]               # [P]

    def __len__(self) -> int:
        return len(self.line_addr)

    @property
    def n_phases(self) -> int:
        return len(self.names)

    def phase(self, p: int) -> Trace:
        s, e = int(self.offsets[p]), int(self.offsets[p + 1])
        return Trace(self.line_addr[s:e], self.is_write[s:e],
                     self.issue[s:e])

    @staticmethod
    def from_phases(phases: Sequence) -> "SegmentedTrace":
        """Build from ``[(name, line_addr, is_write, issue), ...]``
        (or ``(name, Trace)`` pairs); empty phases are dropped."""
        names: List[str] = []
        lines, writes, issues = [], [], []
        for entry in phases:
            if len(entry) == 2:
                name, tr = entry
                la, wr, iss = tr.line_addr, tr.is_write, tr.issue
            else:
                name, la, wr, iss = entry
            if len(la) == 0:
                continue
            names.append(name)
            lines.append(np.asarray(la, dtype=np.int64))
            writes.append(np.asarray(wr, dtype=bool))
            issues.append(np.asarray(iss, dtype=np.int64))
        if not names:
            z = np.empty(0, dtype=np.int64)
            return SegmentedTrace(z, z.astype(bool), z,
                                  np.zeros(1, dtype=np.int64), [])
        offsets = np.zeros(len(names) + 1, dtype=np.int64)
        np.cumsum([len(a) for a in lines], out=offsets[1:])
        return SegmentedTrace(
            np.concatenate(lines), np.concatenate(writes),
            np.concatenate(issues), offsets, names)


# ---------------------------------------------------------------------------
# Vectorized ragged builders: the segment-offset constructions the trace
# models use to emit all partitions' streams without per-partition loops.
# ---------------------------------------------------------------------------

def ragged_arange(counts: np.ndarray) -> np.ndarray:
    """``concat([arange(c) for c in counts])`` without the loop."""
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    starts = np.cumsum(counts) - counts
    return np.arange(total, dtype=np.int64) - np.repeat(starts, counts)


def span_counts(byte_start: np.ndarray, nbytes: np.ndarray):
    """Vectorized ``_line_span`` extents: (first_line, n_lines) per span."""
    byte_start = np.asarray(byte_start, dtype=np.int64)
    nbytes = np.asarray(nbytes, dtype=np.int64)
    first = byte_start // CACHE_LINE_BYTES
    last = (byte_start + np.maximum(nbytes, 1) - 1) // CACHE_LINE_BYTES
    cnt = np.where(nbytes > 0, last - first + 1, 0)
    return first, cnt


def ragged_spans(first: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """``concat([arange(f, f+c) for f, c in zip(first, counts)])``."""
    counts = np.asarray(counts, dtype=np.int64)
    return np.repeat(np.asarray(first, dtype=np.int64),
                     counts) + ragged_arange(counts)


def ragged_bulk(start: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Vectorized :func:`bulk_issue` over groups."""
    return np.repeat(np.asarray(start, dtype=np.int64),
                     np.asarray(counts, dtype=np.int64))


def ragged_spread(start: np.ndarray, window: np.ndarray,
                  counts: np.ndarray) -> np.ndarray:
    """Vectorized :func:`_spread` over groups: group ``g``'s element ``i``
    gets ``start[g] + floor(i * window[g] / counts[g])`` (bit-identical
    float64 arithmetic to the scalar helper)."""
    counts = np.asarray(counts, dtype=np.int64)
    i = ragged_arange(counts).astype(np.float64)
    w = np.repeat(np.asarray(window, dtype=np.float64), counts)
    n = np.repeat(counts.astype(np.float64), counts)
    t = np.repeat(np.asarray(start, dtype=np.float64), counts)
    return (t + i * w / n).astype(np.int64)


def group_ranks(counts: np.ndarray, key: np.ndarray) -> np.ndarray:
    """Rank of each element within its group, preserving input order.

    ``key`` maps each element to its group id; ``counts`` are the group
    sizes (``np.bincount(key, minlength=G)``).
    """
    order = np.argsort(key, kind="stable")
    counts = np.asarray(counts, dtype=np.int64)
    starts = np.cumsum(counts) - counts
    ranks = np.empty(len(key), dtype=np.int64)
    ranks[order] = np.arange(len(key), dtype=np.int64) - np.repeat(
        starts, counts)
    return ranks


def dedup_lines(lines: np.ndarray) -> np.ndarray:
    """Cache-line buffer (Fig. 6e): merge *subsequent* requests to the same
    line into one (consecutive dedup, NOT global unique)."""
    lines = np.asarray(lines, dtype=np.int64)
    if len(lines) == 0:
        return lines
    keep = np.empty(len(lines), dtype=bool)
    keep[0] = True
    np.not_equal(lines[1:], lines[:-1], out=keep[1:])
    return lines[keep]


def rate_limited_issue(
    n: int, start: int, elems_per_cycle: float, elems_per_line: float,
    clock_ratio: float = 1.0,
) -> np.ndarray:
    """Issue-cycle lower bounds for a rate-limited producer (Fig. 6a).

    ``elems_per_cycle`` is the producer rate in elements per *accelerator*
    cycle; ``clock_ratio`` = f_mem / f_acc converts to memory cycles.
    """
    if n == 0:
        return np.empty(0, dtype=np.int64)
    lines_per_acc_cycle = elems_per_cycle / max(elems_per_line, 1e-9)
    mem_cycles_per_line = clock_ratio / max(lines_per_acc_cycle, 1e-9)
    return start + (np.arange(n, dtype=np.float64)
                    * mem_cycles_per_line).astype(np.int64)


def bulk_issue(n: int, start: int) -> np.ndarray:
    """Unlimited producer: all requests available at ``start`` (paper: "the
    requests are just created in bulk")."""
    return np.full(n, start, dtype=np.int64)


def _round_robin_positions(lens: Sequence[int]) -> List[np.ndarray]:
    """Output position of each element under round-robin interleaving.

    Element ``i`` of stream ``s`` lands at ``sum_j min(len_j, i)`` plus the
    rank of ``s`` among streams (in registration order) still alive at
    round ``i``.  Fully vectorized: O(total) with tiny per-stream setup.
    """
    lens = np.asarray(lens, dtype=np.int64)
    order = np.argsort(lens, kind="stable")
    sorted_lens = lens[order]
    prefix = np.concatenate([[0], np.cumsum(sorted_lens)])
    n_streams = len(lens)
    positions: List[np.ndarray] = []
    for s in range(n_streams):
        i = np.arange(lens[s], dtype=np.int64)
        cnt_le = np.searchsorted(sorted_lens, i, side="right")
        base = prefix[cnt_le] + i * (n_streams - cnt_le)
        rank = np.zeros(len(i), dtype=np.int64)
        for t in range(s):
            rank += (lens[t] > i).astype(np.int64)
        positions.append(base + rank)
    return positions


def round_robin_merge(streams: List[np.ndarray]) -> np.ndarray:
    """Round-robin merger (Fig. 6c) over same-dtype 1-D arrays."""
    streams = [np.asarray(s) for s in streams]
    nonempty = [s for s in streams if len(s)]
    if not nonempty:
        return np.empty(0, dtype=np.int64)
    if len(nonempty) == 1:
        return nonempty[0]
    positions = _round_robin_positions([len(s) for s in streams])
    total = sum(len(s) for s in streams)
    out = np.empty(total, dtype=nonempty[0].dtype)
    for s, pos in zip(streams, positions):
        out[pos] = s
    return out


def round_robin_merge_traces(traces: Sequence[Trace]) -> Trace:
    """Round-robin merger over traces (e.g. HitGraph's PE merge)."""
    traces = list(traces)
    if not traces:
        return Trace.empty()
    positions = _round_robin_positions([len(t) for t in traces])
    total = sum(len(t) for t in traces)
    line = np.empty(total, dtype=np.int64)
    wr = np.empty(total, dtype=bool)
    iss = np.empty(total, dtype=np.int64)
    for t, pos in zip(traces, positions):
        line[pos] = t.line_addr
        wr[pos] = t.is_write
        iss[pos] = t.issue
    return Trace(line, wr, iss)


def interleave_issue_ordered(traces: Sequence[Trace]) -> Trace:
    """Priority/issue-order merge: stable sort by issue cycle.

    Used where multiple concurrently-active streams contend (the paper's
    priority merger resolves per-cycle ties; sorting by issue lower bound
    with stable tie-break by stream registration order is the vectorized
    equivalent)."""
    t = Trace.concat(traces)
    if len(t) == 0:
        return t
    order = np.argsort(t.issue, kind="stable")
    return Trace(t.line_addr[order], t.is_write[order], t.issue[order])
