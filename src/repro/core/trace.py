"""Request-trace container and builders.

A :class:`Trace` is the vectorized counterpart of the paper's request
streams: arrays of cache-line addresses + write flags + issue-cycle lower
bounds, in *program order*.  Accelerator models (``core/hitgraph.py``,
``core/accugraph.py``) build traces from per-iteration algorithm statistics
and feed them to ``core/vectorized.py`` / ``kernels/dram_timing``.

Issue-cycle lower bounds encode producer rate limits and phase barriers
(control flow): e.g. an edge reader rate-limited to 8 edges/cycle at
f_acc produces line ``i`` no earlier than ``start + i*lines_per_cycle``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence

import numpy as np

from repro.core.dram import CACHE_LINE_BYTES


@dataclasses.dataclass
class Trace:
    """A request trace in program order (cache-line granularity)."""

    line_addr: np.ndarray          # int64[n]
    is_write: np.ndarray           # bool[n]
    issue: np.ndarray              # int64[n], memory-clock cycles

    def __post_init__(self) -> None:
        self.line_addr = np.asarray(self.line_addr, dtype=np.int64)
        self.is_write = np.asarray(self.is_write, dtype=bool)
        self.issue = np.asarray(self.issue, dtype=np.int64)
        assert len(self.line_addr) == len(self.is_write) == len(self.issue)

    def __len__(self) -> int:
        return len(self.line_addr)

    @property
    def total_bytes(self) -> int:
        return len(self) * CACHE_LINE_BYTES

    @staticmethod
    def empty() -> "Trace":
        z = np.empty(0, dtype=np.int64)
        return Trace(z, z.astype(bool), z)

    @staticmethod
    def concat(traces: Sequence["Trace"]) -> "Trace":
        traces = [t for t in traces if len(t)]
        if not traces:
            return Trace.empty()
        return Trace(
            np.concatenate([t.line_addr for t in traces]),
            np.concatenate([t.is_write for t in traces]),
            np.concatenate([t.issue for t in traces]),
        )


def dedup_lines(lines: np.ndarray) -> np.ndarray:
    """Cache-line buffer (Fig. 6e): merge *subsequent* requests to the same
    line into one (consecutive dedup, NOT global unique)."""
    lines = np.asarray(lines, dtype=np.int64)
    if len(lines) == 0:
        return lines
    keep = np.empty(len(lines), dtype=bool)
    keep[0] = True
    np.not_equal(lines[1:], lines[:-1], out=keep[1:])
    return lines[keep]


def rate_limited_issue(
    n: int, start: int, elems_per_cycle: float, elems_per_line: float,
    clock_ratio: float = 1.0,
) -> np.ndarray:
    """Issue-cycle lower bounds for a rate-limited producer (Fig. 6a).

    ``elems_per_cycle`` is the producer rate in elements per *accelerator*
    cycle; ``clock_ratio`` = f_mem / f_acc converts to memory cycles.
    """
    if n == 0:
        return np.empty(0, dtype=np.int64)
    lines_per_acc_cycle = elems_per_cycle / max(elems_per_line, 1e-9)
    mem_cycles_per_line = clock_ratio / max(lines_per_acc_cycle, 1e-9)
    return start + (np.arange(n, dtype=np.float64)
                    * mem_cycles_per_line).astype(np.int64)


def bulk_issue(n: int, start: int) -> np.ndarray:
    """Unlimited producer: all requests available at ``start`` (paper: "the
    requests are just created in bulk")."""
    return np.full(n, start, dtype=np.int64)


def _round_robin_positions(lens: Sequence[int]) -> List[np.ndarray]:
    """Output position of each element under round-robin interleaving.

    Element ``i`` of stream ``s`` lands at ``sum_j min(len_j, i)`` plus the
    rank of ``s`` among streams (in registration order) still alive at
    round ``i``.  Fully vectorized: O(total) with tiny per-stream setup.
    """
    lens = np.asarray(lens, dtype=np.int64)
    order = np.argsort(lens, kind="stable")
    sorted_lens = lens[order]
    prefix = np.concatenate([[0], np.cumsum(sorted_lens)])
    n_streams = len(lens)
    positions: List[np.ndarray] = []
    for s in range(n_streams):
        i = np.arange(lens[s], dtype=np.int64)
        cnt_le = np.searchsorted(sorted_lens, i, side="right")
        base = prefix[cnt_le] + i * (n_streams - cnt_le)
        rank = np.zeros(len(i), dtype=np.int64)
        for t in range(s):
            rank += (lens[t] > i).astype(np.int64)
        positions.append(base + rank)
    return positions


def round_robin_merge(streams: List[np.ndarray]) -> np.ndarray:
    """Round-robin merger (Fig. 6c) over same-dtype 1-D arrays."""
    streams = [np.asarray(s) for s in streams]
    nonempty = [s for s in streams if len(s)]
    if not nonempty:
        return np.empty(0, dtype=np.int64)
    if len(nonempty) == 1:
        return nonempty[0]
    positions = _round_robin_positions([len(s) for s in streams])
    total = sum(len(s) for s in streams)
    out = np.empty(total, dtype=nonempty[0].dtype)
    for s, pos in zip(streams, positions):
        out[pos] = s
    return out


def round_robin_merge_traces(traces: Sequence[Trace]) -> Trace:
    """Round-robin merger over traces (e.g. HitGraph's PE merge)."""
    traces = list(traces)
    if not traces:
        return Trace.empty()
    positions = _round_robin_positions([len(t) for t in traces])
    total = sum(len(t) for t in traces)
    line = np.empty(total, dtype=np.int64)
    wr = np.empty(total, dtype=bool)
    iss = np.empty(total, dtype=np.int64)
    for t, pos in zip(traces, positions):
        line[pos] = t.line_addr
        wr[pos] = t.is_write
        iss[pos] = t.issue
    return Trace(line, wr, iss)


def interleave_issue_ordered(traces: Sequence[Trace]) -> Trace:
    """Priority/issue-order merge: stable sort by issue cycle.

    Used where multiple concurrently-active streams contend (the paper's
    priority merger resolves per-cycle ties; sorting by issue lower bound
    with stable tie-break by stream registration order is the vectorized
    equivalent)."""
    t = Trace.concat(traces)
    if len(t) == 0:
        return t
    order = np.argsort(t.issue, kind="stable")
    return Trace(t.line_addr[order], t.is_write[order], t.issue[order])
