"""Typed preset-resolution errors shared by every string-named axis.

Every user-facing axis that resolves names against a registry (graph
presets, ordering transforms, memory/cache presets, accelerators,
variants, update streams) raises :class:`UnknownPresetError` on a miss:
a :class:`KeyError` subclass that names the *axis*, lists the valid
names, and suggests the nearest valid preset — so a sweep over a typo'd
grid fails at case construction with an actionable message instead of
deep inside a worker.

Subclassing :class:`KeyError` keeps every existing ``except KeyError``
call site (and test) working unchanged.
"""

from __future__ import annotations

import difflib
from typing import Iterable, Optional


class UnknownPresetError(KeyError):
    """An unknown string name on a preset-resolved axis."""

    def __init__(self, axis: str, name: str, available: Iterable[str]):
        self.axis = axis
        self.name = name
        self.available = sorted(available)
        self.suggestion: Optional[str] = None
        matches = difflib.get_close_matches(name, self.available, n=1,
                                            cutoff=0.5)
        if matches:
            self.suggestion = matches[0]
        msg = (f"unknown {axis} preset {name!r}; "
               f"available: {self.available}")
        if self.suggestion is not None:
            msg += f" (did you mean {self.suggestion!r}?)"
        super().__init__(msg)

    def __str__(self) -> str:        # KeyError quotes its arg by default
        return self.args[0]
