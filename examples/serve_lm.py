"""Batched serving example: prefill + greedy decode with the KV cache.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np
import jax

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import Request, generate


def main():
    cfg = get_config("qwen3_0_6b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, 12).astype(np.int32),
                max_new_tokens=16),
        Request(prompt=rng.integers(0, cfg.vocab, 7).astype(np.int32),
                max_new_tokens=16),
        Request(prompt=rng.integers(0, cfg.vocab, 3).astype(np.int32),
                max_new_tokens=16),
    ]
    out = generate(params, cfg, requests)
    for i, row in enumerate(out):
        print(f"request {i}: prompt_len={len(requests[i].prompt)} "
              f"-> {row.tolist()}")


if __name__ == "__main__":
    main()
