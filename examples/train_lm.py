"""End-to-end LM training driver: ~100M-param qwen3-family model, a few
hundred steps on CPU (or any mesh), with checkpointing, elastic resume,
and straggler monitoring.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
      PYTHONPATH=src python examples/train_lm.py --steps 100 --resume
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed.fault_tolerance import ElasticTrainer
from repro.models import model as M
from repro.train import data as D
from repro.train import optimizer as opt
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    # ~100M params: qwen3 family, reduced depth/width
    cfg = dataclasses.replace(
        get_config("qwen3_0_6b"),
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab=32768, name="qwen3-100m")
    print(f"model: {cfg.name}, ~{cfg.param_count()/1e6:.0f}M params")

    hp = opt.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    dc = D.DataConfig(seq_len=args.seq_len, global_batch=args.batch)

    def build_state(mesh):
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        return params, opt.init(params)

    trainer = ElasticTrainer(
        args.ckpt_dir,
        build_state=build_state,
        make_step=lambda: make_train_step(cfg, hp,
                                          grad_accum=args.grad_accum),
        mesh_builder=lambda: None,
        save_every=50,
    )
    mesh, params, opt_state, start = trainer.resume_or_init()
    if start:
        print(f"resumed from step {start}")

    def batches():
        step = start
        while True:
            yield {k: jnp.asarray(v)
                   for k, v in D.make_batch(cfg, dc, step).items()}
            step += 1

    params, opt_state, losses = trainer.run(
        params, opt_state, batches(), args.steps, start_step=start)
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({len(trainer.monitor.events)} straggler events)")


if __name__ == "__main__":
    main()
