"""Rapid accelerator prototyping (the paper's §5 workflow, extended):
sweep partition sizes, pipeline counts, and DRAM types for AccuGraph in
simulation — minutes instead of an FPGA synthesis cycle — and sanity-
check the shortlist against the O(1) analytical model (§7 future work).

Run:  PYTHONPATH=src python examples/graph_accelerator_study.py
"""

import dataclasses

from repro.algorithms.common import Problem
from repro.core import accugraph, analytical
from repro.core.dram import hbm2
from repro.graphs.generators import rmat

g = rmat(13, 16, seed=1).undirected_view()
print(f"graph: n={g.n} m={g.m}\n")

print("== partition size sweep (WCC) ==")
for q in (1024, 2048, 4096, g.n):
    cfg = accugraph.AccuGraphConfig(partition_elements=q)
    r = accugraph.simulate(g, Problem.WCC, cfg)
    est = analytical.estimate_accugraph(g, Problem.WCC, cfg,
                                        iterations=r.iterations)
    print(f"  q={q:6d}: sim {r.runtime_ms:7.3f} ms  "
          f"analytical {est.runtime_ns/1e6:7.3f} ms ({est.bound})")

print("\n== edge pipelines sweep ==")
for ep in (8, 16, 32):
    cfg = accugraph.AccuGraphConfig(edge_pipelines=ep)
    r = accugraph.simulate(g, Problem.WCC, cfg)
    print(f"  pipelines={ep:2d}: {r.runtime_ms:7.3f} ms "
          f"greps={r.reps/1e9:.2f}")

print("\n== DRAM type (paper §7 future work) ==")
for name, dram in (("ddr4", None), ("hbm2-interleaved", hbm2())):
    cfg = accugraph.AccuGraphConfig(edge_pipelines=64, dram=dram)
    r = accugraph.simulate(g, Problem.WCC, cfg)
    print(f"  {name:18s}: {r.runtime_ms:7.3f} ms greps={r.reps/1e9:.2f}")
print("\n(64 pipelines + HBM shows the bandwidth headroom the 16-pipe")
print(" design cannot use — the [Gh19]-style DRAM/workload interaction)")
