"""Rapid accelerator prototyping (the paper's §5 workflow, extended):
sweep partition sizes, pipeline counts, and DRAM types for AccuGraph in
simulation — minutes instead of an FPGA synthesis cycle — and sanity-
check the shortlist against the O(1) analytical model (§7 future work).

Everything runs through ``repro.sim``: design axes are plain config
overrides, DRAM types are ``memory=`` selectors, and ``sweep()``
deduplicates the shared WCC executions across all design points.

Run:  PYTHONPATH=src python examples/graph_accelerator_study.py
"""

from repro.core import analytical
from repro.graphs.generators import rmat
from repro.sim import (SimSession, SweepCase, Sweeper, get_accelerator,
                       sweep)

g = rmat(13, 16, seed=1).undirected_view()
print(f"graph: n={g.n} m={g.m}\n")

spec = get_accelerator("accugraph")
sweeper = Sweeper()     # shared: one WCC run reused where q coincides

print("== partition size sweep (WCC) ==")
qs = (1024, 2048, 4096, g.n)
rows = sweep(cases=[
    SweepCase(graph=g, problem="wcc", accelerator="accugraph",
              config=spec.make_config(partition_elements=q))
    for q in qs
], sweeper=sweeper)
for q, row in zip(qs, rows):
    est = analytical.estimate_accugraph(g, row.case.problem,
                                        row.case.config,
                                        iterations=row.report.iterations)
    print(f"  q={q:6d}: sim {row.report.runtime_ms:7.3f} ms  "
          f"analytical {est.runtime_ns/1e6:7.3f} ms ({est.bound})")

print("\n== edge pipelines sweep ==")
eps = (8, 16, 32)
rows = sweep(cases=[
    SweepCase(graph=g, problem="wcc", accelerator="accugraph",
              config=spec.make_config(edge_pipelines=ep))
    for ep in eps
], sweeper=sweeper)
for ep, row in zip(eps, rows):
    print(f"  pipelines={ep:2d}: {row.report.runtime_ms:7.3f} ms "
          f"greps={row.report.reps/1e9:.2f}")

print("\n== DRAM type (paper §7 future work) ==")
session = SimSession(g)
for name, memory in (("ddr4", None), ("hbm2-interleaved", "hbm2")):
    r = session.run("wcc", "accugraph", edge_pipelines=64, memory=memory)
    print(f"  {name:18s}: {r.runtime_ms:7.3f} ms greps={r.reps/1e9:.2f}")
print("\n(64 pipelines + HBM shows the bandwidth headroom the 16-pipe")
print(" design cannot use — the [Gh19]-style DRAM/workload interaction)")

print("\n== on-chip cache hierarchy (vertex BRAM sweep, WCC) ==")
# the hierarchy layer is one more sweep axis: cache hits are dropped
# before they reach DRAM, so a BRAM-budget ladder directly charts how
# much of the working set each budget keeps on chip.
for cache in (None, "vertex-64k", "vertex-256k", "vertex-1m", "default"):
    r = session.run("wcc", "accugraph", cache=cache)
    label = cache or "no-cache"
    print(f"  {label:18s}: {r.runtime_ms:7.3f} ms "
          f"hit-rate={r.cache_hit_rate:5.1%} "
          f"dram-requests={r.total_requests}")
print("\n('default' is AccuGraph's declared vertex BRAM; HitGraph's")
print(" default is a stream prefetcher — see repro.sim.CACHE_PRESETS)")
