"""Quickstart: the paper's pipeline end to end in ~30 seconds.

1. build a graph, 2. run WCC on both accelerator models, 3. compare
runtime/REPS (the paper's comparability study in miniature), 4. try the
paper's §5 optimizations, 5. peek at the DRAM statistics the simulation
exposes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.algorithms.common import Problem
from repro.core import accugraph, hitgraph, optimizations
from repro.graphs.generators import rmat

g = rmat(13, 8, seed=0).undirected_view()
print(f"graph: n={g.n}, m={g.m}, avg degree {g.avg_degree:.1f}\n")

hg = hitgraph.simulate(g, Problem.WCC,
                       hitgraph.HitGraphConfig(partition_elements=2048))
ag = accugraph.simulate(g, Problem.WCC,
                        accugraph.AccuGraphConfig(partition_elements=2048))

print("   system    runtime     iters   GREPS   row-hit-rate")
for r in (hg, ag):
    print(f"{r.system:>9s}  {r.runtime_ms:8.3f} ms  {r.iterations:5d} "
          f"  {r.reps / 1e9:5.2f}   {r.row_hit_rate:.3f}")
print("\nNote: HitGraph has 4 DDR3 channels vs AccuGraph's single DDR4"
      "\nchannel here (the papers' own configs) — see"
      " benchmarks/fig12_comparability.py for the equal-config study.\n")

print("paper §5 optimizations (AccuGraph, WCC):")
for res in optimizations.run_study(
        g, Problem.WCC, accugraph.AccuGraphConfig(partition_elements=2048),
        variants=["prefetch_skip", "partition_skip", "both"]):
    print(f"  {res.variant:15s} {res.report.runtime_ms:8.3f} ms "
          f"({res.speedup:.2f}x)")

print("\nper-phase DRAM statistics (AccuGraph, first 4 phases):")
for ph in ag.phases[:4]:
    print(f"  {ph.name:18s} reqs={ph.requests:6d} "
          f"hits={ph.row_hits:6d} conflicts={ph.row_conflicts:4d} "
          f"cycles=[{ph.start_cycle}, {ph.end_cycle}]")
