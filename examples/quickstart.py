"""Quickstart: the paper's pipeline end to end in ~30 seconds, through
the unified ``repro.sim`` API.

The paper's claim is that simulating *memory access patterns* (instead of
cycle-accurate RTL) makes graph-accelerator benchmarking standardized and
comparable.  ``repro.sim`` is that claim as an API surface — one entry
point for every accelerator, memory type, and backend:

    from repro.sim import simulate, sweep, list_accelerators

    simulate(g, "wcc", accelerator="hitgraph")          # one run
    simulate(g, "wcc", accelerator="accugraph",
             memory="hbm2")                             # any memory
    simulate(g, "wcc", accelerator="accugraph",
             backend="event")                           # fidelity check
    sweep(graphs=[g], problems=["wcc"],
          accelerators=["hitgraph", "accugraph"])       # grids, deduped

(The third registered accelerator, ``reference``, is the event-driven
element-granularity fidelity machine — orders of magnitude slower, for
small cross-check graphs only.)

This script walks that surface: 1. build a graph, 2. run WCC on both
vectorized trace models, 3. compare runtime/REPS (the paper's
comparability study in miniature), 4. sweep the paper's §5 optimization
variants, 5. peek at the per-phase DRAM statistics every simulation
exposes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.graphs.generators import rmat
from repro.sim import get_accelerator, list_accelerators, simulate, sweep

g = rmat(13, 8, seed=0).undirected_view()
print(f"graph: n={g.n}, m={g.m}, avg degree {g.avg_degree:.1f}")
print(f"registered accelerators: {list_accelerators()}\n")

hg = simulate(g, "wcc", accelerator="hitgraph", partition_elements=2048)
ag = simulate(g, "wcc", accelerator="accugraph", partition_elements=2048)

print("   system    runtime     iters   GREPS   row-hit-rate")
for r in (hg, ag):
    print(f"{r.system:>9s}  {r.runtime_ms:8.3f} ms  {r.iterations:5d} "
          f"  {r.reps / 1e9:5.2f}   {r.row_hit_rate:.3f}")
print("\nNote: HitGraph has 4 DDR3 channels vs AccuGraph's single DDR4"
      "\nchannel here (the papers' own configs) — see"
      " benchmarks/fig12_comparability.py for the equal-config study.\n")

print("paper §5 optimizations (AccuGraph, WCC), one sweep() call:")
ag_cfg = get_accelerator("accugraph").make_config(partition_elements=2048)
rows = sweep(graphs=[g], problems=["wcc"], accelerators=["accugraph"],
             variants=["baseline", "prefetch_skip", "partition_skip",
                       "both"],
             configs={"accugraph": ag_cfg})
base = rows[0].report.runtime_ns
for row in rows:
    print(f"  {row.variant:15s} {row.report.runtime_ms:8.3f} ms "
          f"({base / max(row.report.runtime_ns, 1e-9):.2f}x)")

print("\nper-phase DRAM statistics (AccuGraph, first 4 phases):")
for ph in ag.phases[:4]:
    print(f"  {ph.name:18s} reqs={ph.requests:6d} "
          f"hits={ph.row_hits:6d} conflicts={ph.row_conflicts:4d} "
          f"cycles=[{ph.start_cycle}, {ph.end_cycle}]")

print("\nevent-driven cross-check (small graph, element granularity):")
gs = rmat(9, 4, seed=0).undirected_view()
for backend in ("vectorized", "event"):
    r = simulate(gs, "wcc", accelerator="accugraph", backend=backend)
    print(f"  {backend:11s} {r.runtime_ms:8.4f} ms")
